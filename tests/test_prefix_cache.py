"""Prefix-cache page sharing + bucketed/chunked prefill (PR 4 tentpole).

Four layers of guarantees:
  * token identity — a prefix-hit serve (shared pages, suffix-only prefill,
    COW on a fully cached prompt) emits exactly the cold serve's greedy
    tokens, for identical and diverging prompts, on one device and under a
    2x2 data x model mesh;
  * pool invariants — refcounts balance through sharing, preemption and
    eviction; refcount-0 cached pages park in the LRU and are reclaimed
    (de-indexed) under pressure, never while referenced;
  * COW isolation — a request decoding against shared prefix pages never
    mutates a sibling's page (decode writes land past the prefix; the one
    writable reused page is a private copy);
  * compile bounds — power-of-two bucketing keeps distinct prefill traces
    <= log2(max_seq_len) across 50 random prompt lengths (counted by the
    engine's trace-time wrapper).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MeshConfig, ServeConfig, get_config
from repro.models import registry
from repro.serving import PagedKVCachePool, ServingEngine
from repro.serving.paged import block_hashes


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


def _sequential_decode(cfg, params, prompt, n_new, cache_len):
    """Unbatched reference: exact-length prefill + single-sequence decode."""
    bundle = registry.build(cfg)
    prefill = jax.jit(bundle.serve_prefill_fn, static_argnames=("cache_len",))
    decode = jax.jit(bundle.decode_fn)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, state = prefill(params, toks, cache_len=cache_len)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, state = decode(params, jnp.asarray([[out[-1]]], jnp.int32),
                               state)
        out.append(int(jnp.argmax(logits[0])))
    return out


# ---------------------------------------------------------------------------
# Config knobs
# ---------------------------------------------------------------------------

def test_serve_config_prefix_cache_requires_pow2_pages():
    with pytest.raises(ValueError) as e:
        ServeConfig(page_size=12, max_seq_len=48, enable_prefix_cache=True)
    assert "page_size" in str(e.value) and "enable_prefix_cache" in str(e.value)
    # the same page size is fine with the cache off...
    ServeConfig(page_size=12, max_seq_len=48, enable_prefix_cache=False)
    # ...and on the slotted layout, where page_size (and the cache) is inert
    ServeConfig(page_size=12, max_seq_len=48, kv_layout="slotted")


def test_serve_config_prefill_chunk_alias_deprecated():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = ServeConfig(prefill_chunk=3)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert cfg.max_prefills_per_step == 3
    assert cfg.prefill_chunk is None          # folded: alias never re-read
    # the alias normalizes, so engine caches key both spellings identically
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert ServeConfig(prefill_chunk=3) == \
            ServeConfig(max_prefills_per_step=3)
        # conflicting pair must fail loudly, not silently drop one value
        with pytest.raises(ValueError, match="conflicting"):
            ServeConfig(max_prefills_per_step=8, prefill_chunk=2)


def test_serve_config_new_knob_validation():
    with pytest.raises(ValueError, match="prefill_chunk_tokens"):
        ServeConfig(prefill_chunk_tokens=-1)
    with pytest.raises(ValueError, match="enable_prefix_cache"):
        ServeConfig(enable_prefix_cache="yes")


def test_block_hashes_chain_commits_to_prefix():
    a = block_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = block_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4)
    assert len(a) == 2 and a[0] == b[0] and a[1] != b[1]
    # a block's hash depends on every earlier block, not just its own tokens
    c = block_hashes([0, 2, 3, 4, 5, 6, 7, 8], 4)
    assert c[1] != a[1]
    assert block_hashes([1, 2, 3], 4) == []   # partial block never hashes


# ---------------------------------------------------------------------------
# Pool invariants
# ---------------------------------------------------------------------------

def _prefix_pool(bundle, slots=3, ps=4, seq=16, **kw):
    return PagedKVCachePool(slots, ps, seq,
                            lambda: bundle.init_decode_state(1, ps),
                            enable_prefix_cache=True, **kw)


def test_pool_refcounts_share_and_release(dense_setup):
    _, bundle, _ = dense_setup
    pool = _prefix_pool(bundle)
    prompt = list(range(100, 110))            # 10 tokens: 2 full + 1 partial
    s0, cached0 = pool.alloc_prefix(0, prompt)
    assert cached0 == 0 and len(pool.held[s0]) == 3
    pool.commit_prefix(s0, prompt)
    # identical prompt: both full blocks shared, partial page private
    s1, cached1 = pool.alloc_prefix(1, prompt)
    assert cached1 == 8
    assert pool.held[s1][:2] == pool.held[s0][:2]       # shared read-only
    assert pool.held[s1][2] != pool.held[s0][2]         # private tail
    shared = pool.held[s0][:2]
    assert all(pool.refcount[p] == 2 for p in shared)
    assert pool.pages_held == 4               # 2 shared (once) + 2 private
    # diverging prompt: first block shared only
    div = prompt[:4] + [7] * 6
    s2, cached2 = pool.alloc_prefix(2, div)
    assert cached2 == 4 and pool.held[s2][0] == shared[0]
    assert pool.refcount[shared[0]] == 3
    # eviction decrements; cached pages park in the LRU, stay indexed
    # (s2 shares only block 0, so the counts diverge per block)
    pool.evict(s1)
    assert pool.refcount[shared[0]] == 2 and pool.refcount[shared[1]] == 1
    pool.evict(s0)
    pool.evict(s2)
    assert int((pool.refcount > 0).sum()) == 0
    assert pool.cached_pages == 2             # s0's two committed blocks
    assert pool.pages_allocated == pool.pages_freed
    # a re-admission pulls them straight back out of the LRU
    s3, cached3 = pool.alloc_prefix(3, prompt)
    assert cached3 == 8 and pool.held[s3][:2] == shared


def test_pool_lru_reclaims_cached_pages_under_pressure(dense_setup):
    _, bundle, _ = dense_setup
    # 5 usable pages; two 2-page prompts fill 4, their blocks stay cached
    pool = _prefix_pool(bundle, slots=2, ps=4, seq=8, num_pages=6)
    a, b = list(range(10, 18)), list(range(20, 28))
    sa, _ = pool.alloc_prefix(0, a)
    pool.commit_prefix(sa, a)
    sb, _ = pool.alloc_prefix(1, b)
    pool.commit_prefix(sb, b)
    pool.evict(sa)
    pool.evict(sb)
    assert pool.cached_pages == 4
    # a third prompt needs 2 fresh pages: only 1 is content-free, so the
    # LRU evicts prompt a's (least recently used) pages and de-indexes them
    c = list(range(30, 38))
    sc, cached = pool.alloc_prefix(2, c)
    assert cached == 0 and pool.cached_pages_evicted >= 1
    # b's chain survived (more recently parked); a's head block is gone
    assert pool._plan(b)[2] > 0 or pool.cached_pages == 0
    assert pool._plan(a)[2] == 0
    # LRU never reclaims a referenced page
    assert all(pool.refcount[p] == 1 for p in pool.held[sc])


def test_pool_index_verifies_hits_against_tokens(dense_setup):
    """A hash collision must degrade to a miss, never map another prompt's
    pages: every index hit is verified against the stored (parent_hash,
    block_tokens) pair."""
    _, bundle, _ = dense_setup
    pool = _prefix_pool(bundle)
    prompt = list(range(60, 68))
    s0, _ = pool.alloc_prefix(0, prompt)
    pool.commit_prefix(s0, prompt)
    (h,) = block_hashes(prompt, 4)[:1]
    # forge a colliding entry: same chain hash, different tokens
    pid, parent, _ = pool._index[h]
    pool._index[h] = (pid, parent, (1, 2, 3, 4))
    assert pool._plan(prompt)[2] == 0         # verified -> miss, not alias
    pool._index[h] = (pid, parent + 1, tuple(prompt[:4]))
    assert pool._plan(prompt)[2] == 0         # parent mismatch -> miss


def test_pool_chunked_commit_cursor_incremental(dense_setup):
    """commit_prefix with a growing prefix (chunked prefill) registers each
    block exactly once and ends at the same index a one-shot commit gives."""
    _, bundle, _ = dense_setup
    pool = _prefix_pool(bundle, slots=2, ps=4, seq=16)
    prompt = list(range(200, 214))            # 14 tokens: 3 full blocks
    s0, _ = pool.alloc_prefix(0, prompt)
    for done in (5, 9, 14):                   # ragged chunk boundaries
        pool.commit_prefix(s0, prompt[:done])
    one_shot = _prefix_pool(bundle, slots=2, ps=4, seq=16)
    s1, _ = one_shot.alloc_prefix(0, prompt)
    one_shot.commit_prefix(s1, prompt)
    assert set(pool._index) == set(one_shot._index)
    assert pool._commit_cursor[s0][0] == 3
    # the chunked chain matches the reference hash chain exactly
    assert [pool._index[h][0] for h in block_hashes(prompt, 4)] == \
        pool.held[s0][:3]


def test_pool_cow_never_maps_source_writable(dense_setup):
    _, bundle, _ = dense_setup
    pool = _prefix_pool(bundle)
    prompt = list(range(50, 58))              # exactly 2 pages
    s0, _ = pool.alloc_prefix(0, prompt)
    pool.commit_prefix(s0, prompt)
    s1, cached = pool.alloc_prefix(1, prompt)
    # fully cached prompt: all but the final token served from cache, and
    # the last block's page is a *copy* — the cached source stays immutable
    assert cached == len(prompt) - 1
    assert pool.cow_copies == 1
    assert pool.held[s1][0] == pool.held[s0][0]
    assert pool.held[s1][1] != pool.held[s0][1]


# ---------------------------------------------------------------------------
# End-to-end token identity
# ---------------------------------------------------------------------------

def _serve(cfg, params, prompts, max_new, *, mesh_cfg=None, **scfg_kw):
    base = dict(max_batch=2, max_seq_len=48, max_new_tokens=max_new,
                decode_steps=2, kv_layout="paged", page_size=8)
    base.update(scfg_kw)
    eng = ServingEngine(cfg, ServeConfig(**base), params=params,
                        mesh_cfg=mesh_cfg)
    return eng, eng.generate(prompts, max_new)


def test_prefix_hit_matches_cold_identical_and_diverging(dense_setup):
    cfg, _, params = dense_setup
    rng = np.random.default_rng(5)
    shared = list(rng.integers(0, cfg.vocab_size, (16,)))   # 2 full pages
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, (t,)))
               for t in (5, 9, 3)]
    prompts.append(list(shared))              # page-aligned: the COW case
    prompts.append(prompts[0])                # identical to an earlier one
    eng, hot = _serve(cfg, params, prompts, 6, enable_prefix_cache=True)
    _, cold = _serve(cfg, params, prompts, 6, enable_prefix_cache=False)
    assert hot == cold
    assert eng.metrics.prefix_hit_tokens > 0
    assert eng.pool.cow_copies >= 1
    # and both match the unbatched sequential reference
    for p, got in zip(prompts, hot):
        assert got == _sequential_decode(cfg, params, p, 6,
                                         eng.pool.padded_len)
    # drain invariants: nothing referenced, counters balanced
    assert int((eng.pool.refcount > 0).sum()) == 0
    assert eng.pool.pages_allocated == eng.pool.pages_freed


def test_prefix_hit_matches_cold_under_mesh(dense_setup):
    cfg, _, params = dense_setup
    rng = np.random.default_rng(6)
    shared = list(rng.integers(0, cfg.vocab_size, (16,)))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, (t,)))
               for t in (6, 4, 6, 8)]
    # conftest forces 8 host devices: 2-way data x 2-way model
    mesh_cfg = MeshConfig(shape=(2, 2), axis_names=("data", "model"))
    em, hot_mesh = _serve(cfg, params, prompts, 4, mesh_cfg=mesh_cfg,
                          max_batch=4, enable_prefix_cache=True)
    _, cold_single = _serve(cfg, params, prompts, 4, max_batch=4,
                            enable_prefix_cache=False)
    assert hot_mesh == cold_single
    assert em.metrics.prefix_hit_tokens > 0


def test_prefix_hit_under_preemption_and_chunked_prefill(dense_setup):
    """Oversubscribed pages + chunked prefill: preempted requests resume
    through their own cached prefix and still emit identical tokens."""
    cfg, _, params = dense_setup
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, cfg.vocab_size, [14, 15])
    eng, outs = _serve(cfg, params, prompts, 12, max_seq_len=32,
                       page_size=4, num_pages=12, prefill_chunk_tokens=6)
    assert eng.metrics.preemptions >= 1
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, params, p, 12,
                                         eng.pool.padded_len)
    assert int((eng.pool.refcount > 0).sum()) == 0
    assert eng.pool.pages_allocated == eng.pool.pages_freed


def test_cow_isolation_sibling_decode_does_not_mutate_shared_pages(dense_setup):
    """Two live requests share prefix pages while both decode; the shared
    pages' device content must be bit-identical before and after."""
    cfg, _, params = dense_setup
    rng = np.random.default_rng(9)
    prompt = list(rng.integers(0, cfg.vocab_size, (16,)))   # 2 full pages
    scfg = ServeConfig(max_batch=2, max_seq_len=48, max_new_tokens=10,
                       decode_steps=1, kv_layout="paged", page_size=8)
    eng = ServingEngine(cfg, scfg, params=params)
    ra = eng.submit(prompt, max_new_tokens=10)
    eng.step()                                # A admitted + committed
    shared = [eng.pool._index[h][0] for h in block_hashes(prompt, 8)]
    assert shared
    snap = {pid: (np.asarray(eng.pool.pages["k"][:, pid]),
                  np.asarray(eng.pool.pages["v"][:, pid]))
            for pid in shared}
    rb = eng.submit(prompt, max_new_tokens=10)
    out = eng.run()
    # B mapped A's pages (refcount 2 while both lived) and decoded its own
    # tokens; the shared prefix pages never saw a write
    for pid, (k0, v0) in snap.items():
        np.testing.assert_array_equal(np.asarray(eng.pool.pages["k"][:, pid]), k0)
        np.testing.assert_array_equal(np.asarray(eng.pool.pages["v"][:, pid]), v0)
    assert out[ra] == out[rb]
    assert out[ra] == _sequential_decode(cfg, params, prompt, 10,
                                         eng.pool.padded_len)


# ---------------------------------------------------------------------------
# Compile bounds (bucketed prefill)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["paged", "slotted"])
def test_bucketing_bounds_prefill_compiles(dense_setup, layout):
    """50 random prompt lengths must trace at most log2(max_seq_len)
    distinct prefill shapes (the per-prompt-length jit explosion this PR
    removes).  Counted by the engine's trace-time wrapper."""
    cfg, _, params = dense_setup
    max_seq = 256
    scfg = ServeConfig(max_batch=4, max_seq_len=max_seq, max_new_tokens=2,
                       decode_steps=1, kv_layout=layout, page_size=8)
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(21)
    lengths = rng.integers(1, max_seq - 2, size=50)
    outs = eng.generate(_prompts(rng, cfg.vocab_size, [int(l) for l in lengths]), 2)
    assert len(outs) == 50 and all(len(t) == 2 for t in outs)
    assert eng.prefill_compiles <= int(np.log2(max_seq))
    assert eng.prefill_compiles >= 2          # the counter actually counts


def test_bucketing_off_compiles_per_length(dense_setup):
    """Sanity check of the counter itself: with bucketing disabled every
    distinct prompt length traces its own prefill."""
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=2, max_seq_len=64, max_new_tokens=2,
                       decode_steps=1, kv_layout="slotted",
                       prefill_bucket=False)
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(23)
    eng.generate(_prompts(rng, cfg.vocab_size, [5, 9, 13, 17]), 2)
    assert eng.prefill_compiles == 4


def test_recurrent_families_skip_bucketing():
    """RWKV's recurrent prefill state would be corrupted by a masked tail:
    the bundle must not declare bucketed_prefill and the engine must fall
    back to exact lengths (correctness over compile count)."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    caps = registry.build(cfg).capabilities()
    assert "bucketed_prefill" not in caps and "prefix_serve" not in caps
    eng = ServingEngine(cfg, ServeConfig(max_batch=2, max_seq_len=24,
                                         max_new_tokens=3, decode_steps=2))
    rng = np.random.default_rng(25)
    prompts = _prompts(rng, cfg.vocab_size, [5, 9])
    outs = eng.generate(prompts, 3)
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, eng.params, p, 3, 24)
