"""TransparentTrainer: strategy consistency, fsdp equivalence, donation,
zero1 vs full-state optimizer equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.transparent import TransparentTrainer
from repro.models import registry

SHAPE = ShapeConfig(name="t", kind="train", seq_len=16, global_batch=8)


def _setup(arch="stablelm-1.6b", **mesh_kw):
    cfg = get_config(arch, smoke=True)
    bundle = registry.build(cfg)
    mesh_cfg = MeshConfig(shape=(2, 2, 2), axis_names=("pod", "data", "model"),
                          **mesh_kw)
    run = RunConfig(model=cfg, shape=SHAPE, mesh=mesh_cfg,
                    optimizer=OptimizerConfig(name="adam", lr=1e-2))
    return TransparentTrainer(run, bundle.loss_fn, bundle.specs), cfg


def _batch(cfg, rng):
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                  jnp.int32)}


def _losses(trainer, cfg, rng, n=3):
    state = trainer.init(0)
    batch = _batch(cfg, rng)
    out = []
    for _ in range(n):
        state, m = trainer.step(state, batch)
        out.append(float(m["loss"]))
    return out


@pytest.fixture(scope="module")
def reference_losses():
    tr, cfg = _setup(allreduce="fused")
    return _losses(tr, cfg, np.random.default_rng(0))


@pytest.mark.parametrize("strategy,tol", [
    ("layerwise", 3e-4), ("bucketed", 3e-4), ("hierarchical", 3e-4),
    ("reduce_scatter", 1e-3), ("compressed", 3e-2),
])
def test_strategies_match_fused(reference_losses, strategy, tol):
    tr, cfg = _setup(allreduce=strategy, bucket_bytes=4096)
    losses = _losses(tr, cfg, np.random.default_rng(0))
    np.testing.assert_allclose(losses, reference_losses, atol=tol)


def test_fsdp_matches_replicated(reference_losses):
    tr, cfg = _setup(dp_mode="fsdp")
    losses = _losses(tr, cfg, np.random.default_rng(0))
    np.testing.assert_allclose(losses, reference_losses, atol=3e-4)


def test_loss_decreases():
    tr, cfg = _setup(allreduce="layerwise")
    losses = _losses(tr, cfg, np.random.default_rng(0), n=5)
    assert losses[-1] < losses[0]


def test_metrics_and_step_counter():
    tr, cfg = _setup(allreduce="layerwise")
    state = tr.init(0)
    batch = _batch(cfg, np.random.default_rng(0))
    state, m = tr.step(state, batch)
    assert int(m["step"]) == 1
    assert float(m["grad_norm"]) > 0
    state, m = tr.step(state, batch)
    assert int(m["step"]) == 2


def test_value_and_grad_transform(mesh222, rng):
    """The drop-in primitive reduces gradients over DP axes."""
    from repro.core.transparent import value_and_grad
    P = jax.sharding.PartitionSpec

    def loss(w, x):
        return jnp.mean((x @ w) ** 2)

    vg = value_and_grad(loss, strategy="fused", axes=("pod", "data"))

    def step(w, x):
        l, g = vg(w, x)
        return g

    sm = jax.shard_map(step, mesh=mesh222,
                       in_specs=(P(), P(("pod", "data"), None)),
                       out_specs=P(), check_vma=False,
                       axis_names={"pod", "data"})
    w = jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)
    g = jax.jit(sm)(w, x)
    gref = jax.grad(loss)(w, x)      # global-batch gradient
    np.testing.assert_allclose(np.asarray(g), np.asarray(gref), atol=1e-5)
