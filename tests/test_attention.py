"""Attention substrate behaviour: chunked==naive, windowed==core,
ring-buffer decode == recomputed prefill, MLA absorbed == expanded."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A


def _naive(q, k, v, causal=True, window=0):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(hd)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= kp <= qp
    if window:
        m &= kp > qp - window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


@pytest.mark.parametrize("S,H,KV,hd,window", [
    (64, 4, 2, 16, 0), (96, 4, 1, 32, 0), (128, 2, 2, 16, 24),
])
def test_attention_core_matches_naive(rng, S, H, KV, hd, window):
    B = 2
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S)
    out = A.attention_core(q, k, v, pos, pos, causal=True, window=window,
                           q_block=32, kv_block=32)
    ref = _naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_windowed_attention_matches_core(rng):
    B, S, H, KV, hd, W = 2, 256, 4, 2, 16, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S)
    out = A.windowed_attention(q, k, v, pos, pos, window=W, q_block=32)
    ref = A.attention_core(q, k, v, pos, pos, causal=True, window=W)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_decode_matches_prefill_recompute(rng):
    """Token-by-token decode through the ring cache must equal full-context
    attention at every step (windowed: only within-window keys)."""
    B, H, KV, hd, W = 1, 2, 1, 16, 8
    T = 20                                   # > window: exercises ring wrap
    cache = A.init_cache(B, W, KV, hd, jnp.float32)
    ks = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    vs = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    qs = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    for t in range(T):
        cache = A.cache_update(cache, ks[:, t:t+1], vs[:, t:t+1])
        out = A.decode_attend(qs[:, t:t+1], cache, window=W)
        lo = max(0, t - W + 1)
        ref = _naive(qs[:, t:t+1],
                     ks[:, lo:t+1], vs[:, lo:t+1], causal=False)
        np.testing.assert_allclose(out, ref, atol=2e-5,
                                   err_msg=f"step {t}")


def test_mla_absorbed_decode_matches_expanded(rng):
    """The latent-space (absorbed) decode path must match materialized
    per-head K/V attention."""
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    m = cfg.mla
    from repro.models.common import init_params
    specs = A.mla_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))

    B, T = 2, 6
    xs = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)) * 0.1, jnp.float32)
    # expanded: run full prefill attention over t tokens, take last output
    # absorbed: feed tokens one at a time through the latent cache
    cache = A.mla_init_cache(B, T, cfg, jnp.float32)
    for t in range(T):
        out_abs, cache = A.mla_apply(cfg, params, xs[:, t:t+1],
                                     jnp.full((B, 1), t, jnp.int32),
                                     cache=cache)
        out_exp, _ = A.mla_apply(cfg, params, xs[:, :t+1],
                                 jnp.arange(t + 1, dtype=jnp.int32))
        np.testing.assert_allclose(out_abs[:, 0], out_exp[:, -1],
                                   atol=5e-4, err_msg=f"step {t}")


def test_cache_positions_track_ring_slots():
    cache = A.init_cache(1, 4, 1, 8, jnp.float32)
    for t in range(9):
        cache = A.cache_update(cache, jnp.ones((1, 1, 1, 8)),
                               jnp.ones((1, 1, 1, 8)))
    # after 9 writes into 4 slots: slots hold positions 8,5,6,7
    assert int(cache["index"]) == 9
    np.testing.assert_array_equal(np.asarray(cache["pos"]), [8, 5, 6, 7])
