"""repro.api — the user-transparent Session facade.

Identity guarantees (the facade adds *zero* numerics of its own):
  * ``Session.train`` is loss-identical to driving ``TransparentTrainer``
    directly, on one device and under a 2x2 mesh;
  * ``Session.serve`` / ``Session.generate`` are token-identical to the raw
    ``ServingEngine``, on one device and under a 2x2 mesh;
plus capability dispatch: families that don't serve fail in one line at
load time (``require=``) or on first use, never mid-run.
"""
import jax
import numpy as np
import pytest

from repro import api
from repro.configs import MeshConfig, ServeConfig, get_config
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.core.transparent import TransparentTrainer
from repro.data.pipeline import make_input_pipeline
from repro.data.readers import synthetic_tokens
from repro.models import registry
from repro.serving import ServingEngine

ARCH = "stablelm-1.6b"
SERVE_ARCH = "qwen2.5-14b"


def _prompts(seed, vocab, lengths):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


# ---------------------------------------------------------------------------
# load / parse_mesh / capabilities
# ---------------------------------------------------------------------------

def test_load_unknown_arch():
    with pytest.raises(KeyError, match="unknown arch"):
        api.load("no-such-arch")


def test_load_applies_model_overrides():
    base = api.load(ARCH, smoke=True)
    s = api.load(ARCH, smoke=True, num_layers=1)
    assert s.model.num_layers == 1 != base.model.num_layers
    assert s.model.name == base.model.name


def test_parse_mesh_forms():
    assert api.parse_mesh(None) is None
    assert api.parse_mesh("") is None
    m = api.parse_mesh("2x2")
    assert m.shape == (2, 2) and m.axis_names == ("data", "model")
    # pure-DP shorthand normalizes to a size-1 model axis: the sharding
    # rules always name "model", so a bare ("data",) mesh cannot run
    assert api.parse_mesh("4").shape == (4, 1)
    assert api.parse_mesh("4").axis_names == ("data", "model")
    assert api.parse_mesh("2x2x2").axis_names == ("pod", "data", "model")
    assert api.parse_mesh((2, 1)).shape == (2, 1)
    assert api.parse_mesh(m) is m
    with pytest.raises(ValueError, match="mesh"):
        api.parse_mesh("2xbanana")
    with pytest.raises(ValueError, match="mesh"):
        api.parse_mesh("2x2x2x2")


def test_pure_dp_mesh_shorthand_runs():
    """Regression: mesh='4' used to build a ('data',)-only mesh that the
    'model'-naming sharding rules rejected at first train/serve."""
    s = api.load(ARCH, smoke=True, mesh="4", num_layers=1)
    assert s.train(steps=1, seq_len=8, global_batch=8).step == 1
    assert len(s.generate([1, 2, 3], max_new=2)) == 2


def test_capabilities_by_family():
    assert api.load(SERVE_ARCH, smoke=True).capabilities() >= \
        {"train", "serve", "paged_serve"}
    # recurrent: serves, but O(1) state has nothing to page
    caps = api.load("rwkv6-1.6b", smoke=True).capabilities()
    assert "serve" in caps and "paged_serve" not in caps
    # encdec/vlm frontends need per-request modality inputs
    assert "serve" not in api.load("whisper-tiny", smoke=True).capabilities()


def test_capability_error_at_load_and_use():
    with pytest.raises(api.CapabilityError, match="doesn't serve"):
        api.load("whisper-tiny", smoke=True, require=("serve",))
    s = api.load("whisper-tiny", smoke=True)        # loading is fine
    with pytest.raises(api.CapabilityError, match="doesn't serve"):
        s.generate([1, 2, 3])
    with pytest.raises(api.CapabilityError, match="doesn't serve"):
        s.serve([[1, 2, 3]])
    # the engine itself dispatches on the declared capability set too
    with pytest.raises(ValueError, match="no serving"):
        ServingEngine(s.model, ServeConfig(max_batch=1, max_seq_len=16))


# ---------------------------------------------------------------------------
# train: loss-identical to the direct TransparentTrainer path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", [None, "2x2"])
def test_session_train_matches_transparent_trainer(mesh):
    steps, seq_len, batch = 3, 16, 8
    cfg = get_config(ARCH, smoke=True)
    ds = synthetic_tokens(cfg.vocab_size, seq_len, num_samples=batch * 8)

    session = api.load(ARCH, smoke=True, mesh=mesh)
    res = session.train(steps=steps, data=ds, seq_len=seq_len,
                        global_batch=batch)
    assert len(res.losses) == steps and res.step == steps

    # hand-wired reference: same bundle, same configs, same data pipeline
    bundle = registry.build(cfg)
    mesh_cfg = api.parse_mesh(mesh) or MeshConfig(
        shape=(1, 1), axis_names=("data", "model"))
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("ref", "train", seq_len, batch),
                    mesh=mesh_cfg,
                    optimizer=OptimizerConfig(name="adam", lr=1e-3))
    trainer = TransparentTrainer.from_bundle(run, bundle)
    state = trainer.init(0)
    it, pf = make_input_pipeline(ds, batch, trainer.mesh, trainer.dp_axes)
    ref = []
    for _, b in zip(range(steps), it):
        state, m = trainer.step(state, b)
        ref.append(float(m["loss"]))
    pf.close()
    assert res.losses == ref                        # identical, not close


def test_session_train_continues_and_reports():
    session = api.load(ARCH, smoke=True)
    r1 = session.train(steps=2, seq_len=16, global_batch=8)
    r2 = session.train(steps=2, seq_len=16, global_batch=8)
    assert (r1.step, r2.step) == (2, 4)             # same state continues
    assert r2.metrics["step"] == 4
    assert "p50_s" in r1.straggler
    # continuation consumes the *next* batches, never a replay:
    # train(2) + train(2) is step-for-step identical to train(4)
    ref = api.load(ARCH, smoke=True).train(steps=4, seq_len=16,
                                           global_batch=8)
    assert r1.losses + r2.losses == ref.losses


# ---------------------------------------------------------------------------
# serve / generate: token-identical to the raw engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", [None, "2x2"])
def test_session_serve_matches_raw_engine(mesh):
    scfg = ServeConfig(max_batch=2, max_seq_len=24, max_new_tokens=4,
                       decode_steps=2, page_size=8)
    session = api.load(SERVE_ARCH, smoke=True, mesh=mesh)
    prompts = _prompts(3, session.model.vocab_size, [5, 9, 7])
    out_api = session.serve(prompts, max_new=4, serve_cfg=scfg)

    raw = ServingEngine(get_config(SERVE_ARCH, smoke=True), scfg,
                        params=session.params,
                        mesh_cfg=api.parse_mesh(mesh))
    assert out_api == raw.generate(prompts, 4)


def test_session_generate_single_prompt_and_batch():
    session = api.load(SERVE_ARCH, smoke=True)
    prompts = _prompts(5, session.model.vocab_size, [6, 8])
    single = session.generate(prompts[0], max_new=3)
    assert isinstance(single[0], int) and len(single) == 3
    batch = session.generate(prompts, max_new=3)
    assert batch[0] == single                       # same engine, same greedy
    # generate goes through the same engine as serve
    assert session.serve(prompts, max_new=3) == batch


def test_session_serve_engine_reuse_and_metrics():
    session = api.load(SERVE_ARCH, smoke=True)
    prompts = _prompts(7, session.model.vocab_size, [4, 6])
    session.serve(prompts, max_new=3)
    eng = session.engine
    assert eng is not None and eng.metrics.summary()["completed"] == 2
    session.serve(prompts, max_new=3)
    assert session.engine is eng                    # same knobs -> same engine
    assert session.engine.metrics.summary()["completed"] == 4
    # varying prompt lengths bucket into the same auto-sized engine
    session.serve(_prompts(8, session.model.vocab_size, [5, 9]), max_new=3)
    assert session.engine is eng


def test_session_serve_cfg_with_overrides_applied():
    from repro.configs import ServeConfig
    session = api.load(SERVE_ARCH, smoke=True)
    prompts = _prompts(9, session.model.vocab_size, [4])
    cfg = ServeConfig(max_batch=1, max_seq_len=16, max_new_tokens=4,
                      page_size=8)
    session.serve(prompts, max_new=2, serve_cfg=cfg, kv_layout="slotted")
    assert session.engine.cfg.kv_layout == "slotted"   # override not dropped
    assert session.engine.cfg.max_seq_len == 16        # base cfg kept


def test_trained_params_flow_into_serving():
    session = api.load(ARCH, smoke=True)
    before = session.generate([1, 2, 3, 4], max_new=3)
    session.train(steps=5, seq_len=16, global_batch=8)
    after = session.generate([1, 2, 3, 4], max_new=3)
    # engines are rebuilt on the trained params (greedy argmax may or may
    # not move for so few steps; the engine cache must have been dropped)
    raw = ServingEngine(session.model,
                        session.engine.cfg, params=session.params)
    assert after == raw.generate([[1, 2, 3, 4]], 3)[0]
    assert len(before) == len(after) == 3
