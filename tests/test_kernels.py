"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps
+ hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (optional extra)")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import lru_scan
from repro.kernels.rglru_scan.ref import lru_scan_ref
from repro.kernels.rwkv6_wkv.ops import wkv6
from repro.kernels.rwkv6_wkv.ref import wkv6_ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, S, H, KV, hd, causal, window, dtype)
    (2, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 96, 4, 4, 32, True, 0, jnp.float32),       # ragged seq vs block
    (2, 256, 4, 1, 64, True, 64, jnp.float32),     # MQA + sliding window
    (1, 128, 2, 2, 128, False, 0, jnp.float32),    # non-causal
    (1, 64, 2, 1, 64, True, 0, jnp.bfloat16),      # bf16 i/o
]


@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,dtype", FLASH_CASES)
def test_flash_attention_matches_ref(rng, B, S, H, KV, hd, causal, window,
                                     dtype):
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    ref = attention_ref(qf.astype(jnp.float32), kf.astype(jnp.float32),
                        vf.astype(jnp.float32), causal=causal, window=window)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


def test_flash_attention_matches_model_attention(rng):
    """The kernel and the traced chunked path must agree (they are swapped
    by use_pallas in models/attention.py)."""
    from repro.models.attention import attention_core
    B, S, H, KV, hd = 1, 64, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                        interpret=True)
    b = attention_core(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(a, b, atol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

LRU_CASES = [
    (2, 64, 32, 16, jnp.float32),
    (1, 100, 129, 32, jnp.float32),    # ragged S and W
    (3, 16, 64, 8, jnp.float32),
    (2, 48, 64, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,W,bt,dtype", LRU_CASES)
def test_lru_scan_matches_ref(rng, B, S, W, bt, dtype):
    la = jnp.asarray(-np.abs(rng.normal(size=(B, S, W))), dtype)
    x = jnp.asarray(rng.normal(size=(B, S, W)), dtype)
    out = lru_scan(la, x, interpret=True, block_t=bt)
    ref = lru_scan_ref(la.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(out, ref, atol=_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 33), st.integers(1, 40),
       st.integers(2, 16))
def test_lru_scan_property(B, S, W, bt):
    """Property: kernel equals oracle for arbitrary (B, S, W, block)."""
    r = np.random.default_rng(S * 100 + W)
    la = jnp.asarray(-np.abs(r.normal(size=(B, S, W))), jnp.float32)
    x = jnp.asarray(r.normal(size=(B, S, W)), jnp.float32)
    out = lru_scan(la, x, interpret=True, block_t=bt)
    ref = lru_scan_ref(la, x)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_lru_model_path_matches_kernel(rng):
    """models.rglru associative-scan path == Pallas kernel path."""
    from repro.configs import get_config
    from repro.models.common import init_params
    from repro.models.rglru import recurrent_block_specs, rg_lru_scan
    cfg = get_config("recurrentgemma-2b", smoke=True)
    p = init_params(recurrent_block_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 24, 64)), jnp.float32)
    y1, h1 = rg_lru_scan(p, x, use_pallas=False)
    from repro.kernels.rglru_scan.ops import lru_scan as lru_kernel
    from repro.models.rglru import _lru_gates
    la, gated = _lru_gates(p, x)
    h = lru_kernel(la, gated, interpret=True)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(h, np.float32), atol=1e-4)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

WKV_CASES = [
    (2, 32, 2, 16, 8, jnp.float32),
    (1, 50, 4, 32, 16, jnp.float32),   # ragged S
    (2, 16, 1, 64, 8, jnp.float32),
    (1, 24, 2, 32, 8, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,hd,bt,dtype", WKV_CASES)
def test_wkv6_matches_ref(rng, B, S, H, hd, bt, dtype):
    r = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(B, S, H, hd)))),
                    jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, hd)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, hd, hd)), jnp.float32)
    out, s_last = wkv6(r, k, v, w, u, s0, interpret=True, block_t=bt)

    def flat(t):
        return t.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    oref, sref = wkv6_ref(flat(r), flat(k), flat(v), flat(w), uf,
                          s0.reshape(B * H, hd, hd))
    np.testing.assert_allclose(out, oref.reshape(B, H, S, hd).transpose(0, 2, 1, 3),
                               atol=max(_tol(dtype), 1e-4) * 10)
    np.testing.assert_allclose(s_last.reshape(B * H, hd, hd), sref, atol=1e-4)


def test_wkv6_state_threading(rng):
    """Splitting a sequence in two and threading the state must equal one
    pass (the invariant prefill/decode relies on)."""
    B, S, H, hd = 1, 16, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.normal(size=(B, S, H, hd)))), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, hd)), jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    full, s_full = wkv6(r, k, v, w, u, s0, interpret=True, block_t=8)
    h = S // 2
    o1, s1 = wkv6(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, s0,
                  interpret=True, block_t=8)
    o2, s2 = wkv6(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s1,
                  interpret=True, block_t=8)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), full, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, atol=1e-4)
