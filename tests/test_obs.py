"""Engine tracing + per-phase attribution (repro.obs).

Four layers of guarantees:
  * tracer mechanics — deterministic timelines under an injectable clock,
    span open/close balance (including stale re-opens and mid-flight
    close_all), bounded ring buffer, engine-track-only phase accounting;
  * exporters — Chrome trace-event schema validity (every event carries
    ph/ts/pid/tid; one track per request; metadata names), phase snapshot
    / coverage math, Prometheus text;
  * disabled path — NULL_TRACER is a strict no-op (shared span singleton,
    no events, zero phase time) and an untraced engine records nothing;
  * end-to-end — a traced engine run keeps every lifecycle span balanced
    through preemption and chunked prefill, its section spans cover
    >= 95% of the engine-loop wall, and the emitted trace loads as JSON.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import ServeConfig, get_config
from repro.models import registry
from repro.obs import (ENGINE_TRACK, NULL_TRACER, NullTracer, Tracer,
                       chrome_trace, phase_coverage, phase_snapshot,
                       prometheus_text, request_track, write_chrome_trace)
from repro.serving import ServingEngine, ServingMetrics


class FakeClock:
    """Deterministic monotone clock: every read advances by ``tick``."""

    def __init__(self, tick=1.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


# ---------------------------------------------------------------------------
# Tracer mechanics
# ---------------------------------------------------------------------------

def test_tracer_deterministic_timeline():
    clk = FakeClock()
    tr = Tracer(clock=clk)            # reset() reads the clock once (t0=1)
    with tr.span("step"):             # enter t=2, exit t=3
        pass
    assert tr.phase_seconds == {"step": 1.0}
    assert tr.phase_counts == {"step": 1}
    ph, name, track, ts, dur, args = tr.events[-1]
    assert (ph, name, track, ts, dur, args) == \
        ("X", "step", ENGINE_TRACK, 2.0, 1.0, None)


def test_tracer_nested_spans_accumulate_independently():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("step"):                       # t=2 .. t=5
        with tr.span("decode.device"):          # t=3 .. t=4
            pass
    assert tr.phase_seconds["step"] == 3.0
    assert tr.phase_seconds["decode.device"] == 1.0
    # inner span closes first: events land in completion order
    assert [e[1] for e in tr.events] == ["decode.device", "step"]


def test_tracer_request_track_spans_do_not_count_as_phases():
    tr = Tracer(clock=FakeClock())
    with tr.span("decode", track=request_track(7)):
        pass
    assert tr.phase_seconds == {}               # engine track only
    assert tr.events[-1][2] == "req7"


def test_tracer_begin_end_balance():
    tr = Tracer(clock=FakeClock())
    rt = request_track(0)
    tr.begin("queued", track=rt)
    assert tr.open_spans() == [(rt, "queued")]
    assert tr.end("queued", track=rt) is True
    assert tr.open_spans() == []
    # closing a never-opened span is a silent no-op (preemption paths
    # close "whichever of prefill/decode is open" unconditionally)
    assert tr.end("decode", track=rt) is False
    assert all(e[1] != "decode" for e in tr.events)


def test_tracer_reopen_closes_stale_span():
    tr = Tracer(clock=FakeClock())
    tr.begin("prefill", track="req1")
    tr.begin("prefill", track="req1")           # stale: auto-closed
    spans = [e for e in tr.events if e[0] == "X"]
    assert len(spans) == 1 and spans[0][5]["reopened"] is True
    assert tr.open_spans() == [("req1", "prefill")]
    tr.close_all(drained=True)
    assert tr.open_spans() == []


def test_tracer_end_merges_args():
    tr = Tracer(clock=FakeClock())
    tr.begin("decode", track="req2", slot=3)
    tr.end("decode", track="req2", tokens=8)
    assert tr.events[-1][5] == {"slot": 3, "tokens": 8}


def test_tracer_ring_buffer_bounds_memory():
    tr = Tracer(clock=FakeClock(), capacity=4)
    for i in range(10):
        tr.instant("ev", i=i)
    assert len(tr.events) == 4 and tr.dropped == 6
    assert [e[5]["i"] for e in tr.events] == [6, 7, 8, 9]   # oldest dropped


def test_tracer_reset_keeps_clock_and_meta():
    clk = FakeClock()
    tr = Tracer(clock=clk, meta={"model": "m"})
    tr.instant("x")
    tr.begin("queued", track="req0")
    tr.reset()
    assert not tr.events and tr.open_spans() == [] and tr.dropped == 0
    assert tr.meta == {"model": "m"} and tr.now() > 0


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def _fake_traced_run():
    """A hand-driven timeline exercising every event kind."""
    tr = Tracer(clock=FakeClock(), meta={"model": "fake"})
    with tr.span("step"):
        with tr.span("admit"):
            tr.instant("pool.page_alloc", page=1, slot=0)
        with tr.span("decode.device"):
            pass
        tr.counter("queue_depth", 3)
    tr.begin("decode", track=request_track(0))
    return tr


def test_chrome_trace_schema():
    tr = _fake_traced_run()
    doc = chrome_trace(tr)
    evs = doc["traceEvents"]
    assert evs, "empty trace"
    for e in evs:
        assert {"ph", "ts", "pid", "tid"} <= set(e), e
    # instant events are scoped; counters carry their value
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["args"]["page"] == 1
    ctr = next(e for e in evs if e["ph"] == "C")
    assert ctr["args"]["value"] == 3.0
    # the still-open request span exports as unfinished, not a dangling B
    open_ev = next(e for e in evs if e.get("args", {}).get("unfinished"))
    assert open_ev["ph"] == "X" and open_ev["name"] == "decode"
    assert not any(e["ph"] == "B" for e in evs)
    # engine track is tid 0; the request track got its own tid + name
    names = {e["args"]["name"]: e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names[ENGINE_TRACK] == 0 and "req0" in names
    assert doc["otherData"]["model"] == "fake"
    json.dumps(doc)                               # serializable end to end


def test_write_chrome_trace_roundtrip(tmp_path):
    tr = _fake_traced_run()
    tr.close_all()       # an open span's export reads the (advancing) clock
    p = write_chrome_trace(tr, str(tmp_path / "t.json"))
    loaded = json.loads((tmp_path / "t.json").read_text())
    assert p.endswith("t.json")
    assert loaded == json.loads(json.dumps(chrome_trace(tr)))


def test_phase_snapshot_and_coverage_math():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("step"):                        # 11 ticks total
        with tr.span("step.plan"):               # pipeline section: 3 ticks
            with tr.span("plan"):                # leaf inside a section
                pass
        with tr.span("step.submit"):             # section: 3 ticks
            with tr.span("decode.device"):       # leaf: 1 tick
                pass
        with tr.span("step.retire"):             # section: 1 tick
            pass
    snap = phase_snapshot(tr)
    assert snap["step_time_s"] == 11.0
    assert snap["plan_time_s"] == 1.0
    assert snap["decode_time_s"] == 1.0
    assert snap["prefill_time_s"] == 0.0
    assert snap["other_time_s"] == 9.0           # step - leaves
    assert snap["host_overhead_frac"] == pytest.approx(9.0 / 11.0)
    # coverage counts the pipeline sections (3 + 3 + 1 = 7) over step
    assert phase_coverage(tr) == pytest.approx(7.0 / 11.0)
    assert phase_coverage(Tracer(clock=FakeClock())) == 1.0   # nothing traced


def test_prometheus_text_exposition():
    tr = _fake_traced_run()
    m = ServingMetrics(clock=FakeClock(), tracer=tr)
    txt = prometheus_text(m.summary(), tr)
    assert "repro_serving_tokens_per_sec 0.0" in txt
    assert 'repro_serving_phase_seconds{phase="step"}' in txt
    assert 'repro_serving_phase_calls{phase="decode.device"} 1' in txt


# ---------------------------------------------------------------------------
# Disabled path (NULL_TRACER)
# ---------------------------------------------------------------------------

def test_null_tracer_is_strict_noop():
    n = NULL_TRACER
    assert isinstance(n, NullTracer) and n.enabled is False
    # one shared context-manager singleton: no per-call allocation
    assert n.span("a") is n.span("b", track="req1", x=1)
    with n.span("step"):
        pass
    n.begin("queued", track="req0")
    assert n.end("queued", track="req0") is False
    n.instant("pool.cow", src=1, dst=2)
    n.counter("queue_depth", 5)
    assert n.events == () and n.phase_seconds == {} and n.open_spans() == []
    assert n.close_all() == 0 and n.now() == 0.0
    # exporters accept it without branches
    assert phase_snapshot(n) == {"step_time_s": 0.0, "plan_time_s": 0.0,
                                 "draft_time_s": 0.0,
                                 "prefill_time_s": 0.0, "decode_time_s": 0.0,
                                 "verify_time_s": 0.0,
                                 "other_time_s": 0.0,
                                 "host_overhead_frac": 0.0}
    assert phase_coverage(n) == 1.0


def test_metrics_summary_stable_schema_untraced():
    """Rate splits report honest zeros untraced; int counters stay ints;
    a rejected-everything run divides nothing by zero."""
    m = ServingMetrics(clock=FakeClock())      # tracer=None -> NULL path
    m.record_reject()
    s = m.summary()
    assert s["rejected"] == 1 and s["elapsed_s"] == 0.0
    assert s["tokens_per_sec"] == 0.0
    assert s["decode_tokens_per_sec"] == 0.0
    assert s["prefill_tokens_per_sec"] == 0.0
    assert s["step_time_s"] == 0.0 and s["other_time_s"] == 0.0
    assert isinstance(s["completed"], int)
    assert isinstance(s["decode_tokens"], int)


def test_metrics_split_rates_use_traced_phase_time():
    tr = Tracer(clock=FakeClock())
    m = ServingMetrics(clock=FakeClock(), tracer=tr)
    with tr.span("decode.device"):              # 1 fake second
        pass
    with tr.span("prefill.device"):             # 1 fake second
        pass
    m.record_prefill(8)
    for _ in range(4):
        m.record_decode_token()
    s = m.summary()
    assert s["decode_tokens_per_sec"] == 4.0
    assert s["prefill_tokens_per_sec"] == 8.0


# ---------------------------------------------------------------------------
# End-to-end: traced engine runs
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, params


def test_engine_untraced_records_nothing(dense_setup):
    cfg, params = dense_setup
    scfg = ServeConfig(max_batch=2, max_seq_len=32, max_new_tokens=4,
                       decode_steps=2, page_size=8)
    eng = ServingEngine(cfg, scfg, params=params)
    assert eng.tracer is NULL_TRACER
    rng = np.random.default_rng(0)
    eng.generate(_prompts(rng, cfg.vocab_size, [5, 9]), 4)
    assert eng.tracer.events == ()
    assert eng.save_trace("/nonexistent/never-written.json") is None
    s = eng.metrics.summary()
    assert s["step_time_s"] == 0.0 and s["decode_tokens_per_sec"] == 0.0


def test_engine_traced_spans_balance_and_cover(dense_setup, tmp_path):
    """Chunked prefill + prefix sharing + mid-prefill completion, traced:
    lifecycle spans all close, sections cover >= 95% of the step wall,
    and the trace exports schema-valid."""
    cfg, params = dense_setup
    scfg = ServeConfig(max_batch=2, max_seq_len=64, max_new_tokens=4,
                       decode_steps=2, page_size=8,
                       prefill_chunk_tokens=8, enable_prefix_cache=True,
                       trace=True)
    eng = ServingEngine(cfg, scfg, params=params)
    assert eng.tracer.enabled and eng.paged
    rng = np.random.default_rng(3)
    shared = list(rng.integers(0, cfg.vocab_size, (16,)))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, (t,)))
               for t in (9, 5, 13)] + [[7] * 3]
    outs = eng.generate(prompts, 4)
    assert all(len(o) == 4 for o in outs)
    tr = eng.tracer
    assert tr.open_spans() == []                 # drained -> balanced
    assert phase_coverage(tr) >= 0.95
    s = eng.metrics.summary()
    assert s["step_time_s"] > 0
    assert s["step_time_s"] == pytest.approx(
        s["plan_time_s"] + s["draft_time_s"] + s["prefill_time_s"]
        + s["decode_time_s"] + s["verify_time_s"] + s["other_time_s"])
    # every decode-loop token is attributed; first tokens come from prefill
    assert s["decode_tokens"] == s["tokens_out"] - s["completed"]
    assert s["decode_tokens_per_sec"] > 0 and s["prefill_tokens_per_sec"] > 0
    names = {e[1] for e in tr.events}
    assert {"step", "step.plan", "step.submit", "step.retire", "admit",
            "prefill", "decode.device", "plan", "prefill.device",
            "prefill.chunk", "queued", "decode", "request.complete",
            "pool.page_alloc", "pool.prefix_hit"} <= names
    # one lifecycle track per request, all schema-valid
    doc = json.loads(write_chrome_trace(tr, str(tmp_path / "e.json"))
                     and (tmp_path / "e.json").read_text())
    evs = doc["traceEvents"]
    assert all({"ph", "ts", "pid", "tid"} <= set(e) for e in evs)
    tracks = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {request_track(r) for r in range(len(prompts))} <= tracks


def test_engine_traced_preemption_keeps_spans_balanced(dense_setup):
    """Page pressure forces eviction mid-run (including mid-prefill): the
    victim's open span closes (preempted=True), it re-queues, and the
    drained engine ends with zero open spans and identical tokens."""
    cfg, params = dense_setup
    base = ServeConfig(max_batch=2, max_seq_len=32, max_new_tokens=12,
                       decode_steps=2, kv_layout="paged", page_size=4,
                       num_pages=12)             # worst case would need 17
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, cfg.vocab_size, [14, 15])
    traced = ServingEngine(cfg, base.replace(trace=True), params=params)
    outs = traced.generate(prompts, 12)
    assert traced.metrics.preemptions >= 1
    tr = traced.tracer
    assert tr.open_spans() == []
    names = {e[1] for e in tr.events}
    assert "request.preempt" in names and "queue.push_front" in names
    preempted = [e for e in tr.events
                 if e[0] == "X" and e[5] and e[5].get("preempted")]
    assert preempted, "no span recorded the preemption"
    # tracing must not perturb scheduling decisions or tokens
    assert outs == ServingEngine(cfg, base, params=params).generate(
        prompts, 12)
    json.dumps(chrome_trace(tr))


def test_serve_config_trace_knobs_validate():
    ServeConfig(trace=True, trace_capacity=1024).validate()
    with pytest.raises(ValueError):
        ServeConfig(trace_capacity=0).validate()
    with pytest.raises(ValueError):
        ServeConfig(trace="yes").validate()


def test_session_trace_passthrough(tmp_path):
    """The Session surface: serve(..., trace=True) keys a traced engine,
    session.save_trace writes the Perfetto JSON."""
    from repro.api import load
    sess = load("qwen2.5-14b", smoke=True, require=("serve",))
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, sess.model.vocab_size, [6, 10])
    sess.serve(prompts, max_new=3)
    assert sess.tracer is NULL_TRACER and sess.save_trace("x") is None
    sess.serve(prompts, max_new=3, trace=True)
    assert sess.tracer.enabled
    p = sess.save_trace(str(tmp_path / "s.json"))
    doc = json.loads((tmp_path / "s.json").read_text())
    assert p and doc["traceEvents"]
