"""Paged-attention kernel family (repro.kernels.paged_attention).

The Pallas kernel (interpret mode) must match the pure-jnp oracle, the
oracle must match the slotted ring-cache decode path on identical K/V
(the invariant behind paged == slotted engine equivalence), and the
reserved trash page must be unreadable through any valid (table, length)
pair.  No hypothesis dependency — these always run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_mla_attention,
                                               paged_mla_prefill,
                                               paged_prefill,
                                               paged_ring_prefill)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_mla_attention_ref,
                                               paged_mla_prefill_ref,
                                               paged_prefill_ref,
                                               paged_ring_prefill_ref,
                                               ring_positions)


def _paged_case(rng, slots, H, KV, hd, ps, n, dtype):
    """Random pool + disjoint per-slot page tables + random lengths."""
    P = slots * n + 1                               # page 0 = trash
    q = jnp.asarray(rng.normal(size=(slots, H, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(P, ps, KV, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(P, ps, KV, hd)), dtype)
    lengths = np.asarray(rng.integers(1, n * ps + 1, size=slots), np.int32)
    table = np.zeros((slots, n), np.int32)
    pid = 1
    for s in range(slots):
        for i in range(-(-int(lengths[s]) // ps)):
            table[s, i] = pid
            pid += 1
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lengths)


PAGED_CASES = [
    # (slots, H, KV, hd, ps, n_table, dtype)
    (3, 4, 2, 32, 8, 4, jnp.float32),
    (2, 8, 8, 16, 4, 3, jnp.float32),     # MHA (G = 1)
    (1, 2, 1, 64, 16, 2, jnp.float32),    # MQA
    (4, 4, 2, 32, 8, 1, jnp.float32),     # single-page table
    (2, 4, 2, 32, 8, 3, jnp.bfloat16),    # bf16 i/o
]


@pytest.mark.parametrize("slots,H,KV,hd,ps,n,dtype", PAGED_CASES)
def test_paged_kernel_matches_ref(rng, slots, H, KV, hd, ps, n, dtype):
    q, kp, vp, table, lengths = _paged_case(rng, slots, H, KV, hd, ps, n,
                                            dtype)
    ref = paged_attention_ref(q, kp, vp, table, lengths)
    out = paged_attention(q, kp, vp, table, lengths, use_kernel=True,
                          interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_paged_ref_matches_ring_cache_decode(rng):
    """Pages cut from a contiguous ring cache score identically to
    ``decode_attend`` over that cache — the slotted/paged bridge."""
    from repro.models.attention import decode_attend
    H, KV, hd, ps, n = 4, 2, 32, 8, 3
    Lc = n * ps
    m = 13                                           # valid tokens
    k = jnp.asarray(rng.normal(size=(1, Lc, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, Lc, KV, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, 1, H, hd)), jnp.float32)
    pos = jnp.where(jnp.arange(Lc) < m, jnp.arange(Lc), -1).astype(jnp.int32)
    cache = {"k": k, "v": v, "pos": pos, "index": jnp.asarray(m, jnp.int32)}
    ring = decode_attend(q, cache)                   # [1, 1, H, hd]

    kp = jnp.concatenate([jnp.zeros((1, ps, KV, hd)),
                          k[0].reshape(n, ps, KV, hd)])   # page 0 = trash
    vp = jnp.concatenate([jnp.zeros((1, ps, KV, hd)),
                          v[0].reshape(n, ps, KV, hd)])
    table = jnp.asarray([[1, 2, 3]], jnp.int32)
    paged = paged_attention(q[:, 0], kp, vp, table,
                            jnp.asarray([m], jnp.int32))
    np.testing.assert_allclose(np.asarray(paged), np.asarray(ring[:, 0]),
                               atol=1e-5)


@pytest.mark.parametrize("m", [5, 8, 13, 24, 29])
def test_paged_window_ref_matches_ring_cache_decode(rng, m):
    """Ring-wrapped window pages score identically to ``decode_attend``
    with a window over the equivalent slotted ring cache — the windowed
    slotted/paged bridge, across pre-wrap, exact-wrap and wrapped fills."""
    from repro.models.attention import decode_attend
    H, KV, hd, ps, n = 4, 2, 32, 8, 3
    window = n * ps                                  # 24 = ring capacity
    Lc = window
    q = jnp.asarray(rng.normal(size=(1, 1, H, hd)), jnp.float32)
    # write positions 0..m-1 into the slotted ring (slot = pos % Lc)
    k = jnp.zeros((1, Lc, KV, hd))
    v = jnp.zeros((1, Lc, KV, hd))
    pos = np.full((Lc,), -1, np.int32)
    for p_ in range(m):
        k = k.at[:, p_ % Lc].set(rng.normal(size=(KV, hd)))
        v = v.at[:, p_ % Lc].set(rng.normal(size=(KV, hd)))
        pos[p_ % Lc] = p_
    cache = {"k": k, "v": v, "pos": jnp.asarray(pos),
             "index": jnp.asarray(m, jnp.int32)}
    ring = decode_attend(q, cache, window=window)

    kp = jnp.concatenate([jnp.zeros((1, ps, KV, hd)),
                          k[0].reshape(n, ps, KV, hd)])
    vp = jnp.concatenate([jnp.zeros((1, ps, KV, hd)),
                          v[0].reshape(n, ps, KV, hd)])
    table = jnp.asarray([[1, 2, 3]], jnp.int32)
    lengths = jnp.asarray([m], jnp.int32)
    for use_kernel in (False, True):
        paged = paged_attention(q[:, 0], kp, vp, table, lengths,
                                window=window, use_kernel=use_kernel,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(paged), np.asarray(ring[:, 0]),
                                   atol=2e-5)


def _quantized_pages(kp, vp):
    """Quantize a random fp pool the way the pool's write paths do."""
    from repro.serving.layouts import quantize_kv
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)
    return kq, vq, ks, vs


@pytest.mark.parametrize("window", [0, 24], ids=["full", "ring"])
@pytest.mark.parametrize("slots,H,KV,hd,ps,n,dtype", PAGED_CASES[:2])
def test_paged_kernel_matches_ref_quantized(rng, slots, H, KV, hd, ps, n,
                                            dtype, window):
    """Int8 pages + per-row scales: the fused-dequant kernel must match the
    fused-dequant oracle on both page geometries (full and ring)."""
    if window and window != n * ps:
        window = n * ps
    q, kp, vp, table, lengths = _paged_case(rng, slots, H, KV, hd, ps, n,
                                            dtype)
    kq, vq, ks, vs = _quantized_pages(kp, vp)
    ref = paged_attention_ref(q, kq, vq, table, lengths, window=window,
                              k_scale=ks, v_scale=vs)
    out = paged_attention(q, kq, vq, table, lengths, window=window,
                          k_scale=ks, v_scale=vs, use_kernel=True,
                          interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_paged_kernel_quantized_tracks_fp(rng):
    """The fused int8 path is an *approximation* of fp attention — its
    output must track the fp oracle on the same pre-quantization pool
    within int8 resolution (loose tolerance, the accuracy argument)."""
    q, kp, vp, table, lengths = _paged_case(rng, 3, 4, 2, 32, 8, 4,
                                            jnp.float32)
    kq, vq, ks, vs = _quantized_pages(kp, vp)
    fp = paged_attention_ref(q, kp, vp, table, lengths)
    qd = paged_attention_ref(q, kq, vq, table, lengths, k_scale=ks,
                             v_scale=vs)
    np.testing.assert_allclose(np.asarray(qd), np.asarray(fp),
                               atol=0.05, rtol=0.05)


def test_ring_positions_formula():
    """Each ring index resolves to the latest written position congruent to
    it; never-written cells come back invalid."""
    p, valid = ring_positions(jnp.asarray([5, 8, 13], jnp.int32), 8, 8)
    p, valid = np.asarray(p), np.asarray(valid)
    np.testing.assert_array_equal(p[0][:5], np.arange(5))    # pre-wrap
    assert not valid[0][5:].any()
    np.testing.assert_array_equal(p[1], np.arange(8))        # exact fill
    np.testing.assert_array_equal(p[2], [8, 9, 10, 11, 12, 5, 6, 7])


def test_paged_window_kernel_matches_ref(rng):
    slots, H, KV, hd, ps, n = 3, 4, 2, 32, 8, 3
    window = n * ps
    q, kp, vp, table, lengths = _paged_case(rng, slots, H, KV, hd, ps, n,
                                            jnp.float32)
    ref = paged_attention_ref(q, kp, vp, table, lengths, window=window)
    out = paged_attention(q, kp, vp, table, lengths, window=window,
                          use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("slots,H,R,rp,ps,n", [
    (3, 4, 32, 8, 8, 4),
    (2, 8, 16, 16, 4, 3),
    (1, 2, 64, 8, 16, 2),
])
def test_paged_mla_kernel_matches_ref(rng, slots, H, R, rp, ps, n):
    """Latent-page (absorbed MLA) decode kernel vs the jnp oracle."""
    P = slots * n + 1
    q_lat = jnp.asarray(rng.normal(size=(slots, H, R)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(slots, H, rp)), jnp.float32)
    ckv = jnp.asarray(rng.normal(size=(P, ps, R)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(P, ps, rp)), jnp.float32)
    lengths = np.asarray(rng.integers(1, n * ps + 1, size=slots), np.int32)
    table = np.zeros((slots, n), np.int32)
    pid = 1
    for s in range(slots):
        for i in range(-(-int(lengths[s]) // ps)):
            table[s, i] = pid
            pid += 1
    table, lengths = jnp.asarray(table), jnp.asarray(lengths)
    scale = (R + rp) ** -0.5
    ref = paged_mla_attention_ref(q_lat, q_rope, ckv, kr, table, lengths,
                                  scale=scale)
    out = paged_mla_attention(q_lat, q_rope, ckv, kr, table, lengths,
                              scale=scale, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_mla_trash_page_never_read(rng):
    """Garbage in latent page 0 must not leak through any valid table."""
    slots, H, R, rp, ps, n = 2, 4, 32, 8, 8, 3
    P = slots * n + 1
    q_lat = jnp.asarray(rng.normal(size=(slots, H, R)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(slots, H, rp)), jnp.float32)
    ckv = jnp.asarray(rng.normal(size=(P, ps, R)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(P, ps, rp)), jnp.float32)
    lengths = jnp.asarray([5, 17], jnp.int32)
    table = jnp.asarray([[1, 0, 0], [2, 3, 4]], jnp.int32)
    base = paged_mla_attention(q_lat, q_rope, ckv, kr, table, lengths,
                               scale=0.1)
    ckv2 = ckv.at[0].set(1e4)
    kr2 = kr.at[0].set(-1e4)
    for use_kernel in (False, True):
        out = paged_mla_attention(q_lat, q_rope, ckv2, kr2, table, lengths,
                                  scale=0.1, use_kernel=use_kernel,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=2e-5)


def test_trash_page_never_read(rng):
    """Garbage in page 0 (the write sink for empty slots and padding) must
    not leak into any slot's output."""
    q, kp, vp, table, lengths = _paged_case(rng, 3, 4, 2, 32, 8, 4,
                                            jnp.float32)
    base = paged_attention(q, kp, vp, table, lengths)
    kp2 = kp.at[0].set(1e4)
    vp2 = vp.at[0].set(-1e4)
    for use_kernel in (False, True):
        out = paged_attention(q, kp2, vp2, table, lengths,
                              use_kernel=use_kernel, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=2e-5)

# ---------------------------------------------------------------------------
# Chunked prefill (one request's bucketed chunk; rows >= n_valid are bucket
# padding — undefined by contract, so every comparison slices [:n_valid])
# ---------------------------------------------------------------------------

def _prefill_case(rng, H, KV, hd, ps, n, S, dtype=jnp.float32):
    """Random page pool + the request's table row [n] (pages 1..n)."""
    q = jnp.asarray(rng.normal(size=(S, H, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(n + 1, ps, KV, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(n + 1, ps, KV, hd)), dtype)
    table = jnp.arange(1, n + 1, dtype=jnp.int32)
    return q, kp, vp, table


PREFILL_CASES = [
    # (H, KV, hd, ps, n, S, start, n_valid, dtype)
    (4, 2, 32, 8, 4, 16, 0, 16, jnp.float32),    # cold, full bucket
    (4, 2, 32, 8, 4, 16, 10, 13, jnp.float32),   # deep start + padded tail
    (8, 8, 16, 4, 8, 8, 3, 5, jnp.float32),      # MHA (G = 1)
    (2, 1, 64, 16, 2, 16, 0, 9, jnp.float32),    # MQA, padded tail
    (4, 2, 32, 8, 4, 8, 17, 8, jnp.bfloat16),    # bf16 i/o, deep start
    # multi-q-block bucket: S=256 -> q_block=128; n_valid=100 leaves the
    # second block fully padded (grid-level skip: bucket-tail waste fix)
    (4, 2, 32, 16, 7, 256, 0, 100, jnp.float32),
]


@pytest.mark.parametrize("H,KV,hd,ps,n,S,start,n_valid,dtype",
                         PREFILL_CASES)
def test_paged_prefill_kernel_matches_ref(rng, H, KV, hd, ps, n, S, start,
                                          n_valid, dtype):
    q, kp, vp, table = _prefill_case(rng, H, KV, hd, ps, n, S, dtype)
    assert start + n_valid <= n * ps
    ref = paged_prefill_ref(q, kp, vp, table, start, n_valid)
    out = paged_prefill(q, kp, vp, table, start, n_valid, use_kernel=True,
                        interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out[:n_valid], np.float32),
                               np.asarray(ref[:n_valid], np.float32),
                               atol=atol)


def test_paged_prefill_padded_qblocks_emit_zero(rng):
    """Fully padded q blocks are skipped at grid level and emit exact
    zeros — the bucket tail costs no MXU cycles (and no garbage)."""
    H, KV, hd, ps, n, S, n_valid = 4, 2, 32, 16, 7, 256, 100
    q, kp, vp, table = _prefill_case(rng, H, KV, hd, ps, n, S)
    out = paged_prefill(q, kp, vp, table, 0, n_valid, use_kernel=True,
                        interpret=True)
    # q_block = 128: rows 128..255 form an entirely-padded block
    np.testing.assert_array_equal(np.asarray(out[128:]), 0.0)


def test_paged_prefill_trash_page_never_read(rng):
    """Table tail entries point at page 0; garbage there must not leak
    into any valid row (kernel skips those pages entirely)."""
    H, KV, hd, ps, n, S = 4, 2, 32, 8, 4, 16
    start, n_valid = 3, 10                        # occupies pages 1..2
    q, kp, vp, _ = _prefill_case(rng, H, KV, hd, ps, n, S)
    table = jnp.asarray([1, 2, 0, 0], jnp.int32)  # tail = trash
    base = paged_prefill(q, kp, vp, table, start, n_valid)
    kp2 = kp.at[0].set(1e4)
    vp2 = vp.at[0].set(-1e4)
    for use_kernel in (False, True):
        out = paged_prefill(q, kp2, vp2, table, start, n_valid,
                            use_kernel=use_kernel, interpret=True)
        np.testing.assert_allclose(np.asarray(out[:n_valid]),
                                   np.asarray(base[:n_valid]), atol=2e-5)


@pytest.mark.parametrize("H,KV,hd,ps,n,S,start,n_valid,dtype",
                         PREFILL_CASES[:2] + PREFILL_CASES[-1:])
def test_paged_prefill_kernel_matches_ref_quantized(rng, H, KV, hd, ps, n,
                                                    S, start, n_valid,
                                                    dtype):
    """Chunked prefill against int8 pages: fused-dequant kernel vs the
    fused-dequant oracle (incl. the multi-q-block padded-tail case)."""
    q, kp, vp, table = _prefill_case(rng, H, KV, hd, ps, n, S, dtype)
    kq, vq, ks, vs = _quantized_pages(kp, vp)
    ref = paged_prefill_ref(q, kq, vq, table, start, n_valid, k_scale=ks,
                            v_scale=vs)
    out = paged_prefill(q, kq, vq, table, start, n_valid, k_scale=ks,
                        v_scale=vs, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:n_valid], np.float32),
                               np.asarray(ref[:n_valid], np.float32),
                               atol=2e-5)


RING_PREFILL_CASES = [
    # (H, KV, hd, ps, n, S, start, n_valid) — window = n * ps
    (4, 2, 32, 8, 3, 16, 0, 16),     # cold start (ring empty)
    (4, 2, 32, 8, 3, 16, 30, 13),    # ring fully wrapped before the chunk
    (8, 8, 16, 4, 4, 8, 10, 5),      # MHA, partially filled ring
    (2, 1, 64, 8, 2, 32, 70, 27),    # chunk wider than the window (S > w)
]


@pytest.mark.parametrize("H,KV,hd,ps,n,S,start,n_valid",
                         RING_PREFILL_CASES)
def test_paged_ring_prefill_kernel_matches_ref(rng, H, KV, hd, ps, n, S,
                                               start, n_valid):
    window = n * ps
    q, kp, vp, table = _prefill_case(rng, H, KV, hd, ps, n, S)
    ck = jnp.asarray(rng.normal(size=(S, KV, hd)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(S, KV, hd)), jnp.float32)
    ref = paged_ring_prefill_ref(q, kp, vp, ck, cv, table, start, n_valid,
                                 window=window)
    out = paged_ring_prefill(q, kp, vp, ck, cv, table, start, n_valid,
                             window=window, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:n_valid]),
                               np.asarray(ref[:n_valid]), atol=2e-5)


@pytest.mark.parametrize("H,KV,hd,ps,n,S,start,n_valid",
                         RING_PREFILL_CASES)
def test_paged_ring_prefill_kernel_matches_ref_quantized(rng, H, KV, hd,
                                                         ps, n, S, start,
                                                         n_valid):
    """Ring chunked prefill with an int8 *snapshot*: the ring pages carry
    scales, the chunk's ride-along K/V stay fp (scale 1) — kernel vs
    oracle across cold, wrapped and wider-than-window chunks."""
    window = n * ps
    q, kp, vp, table = _prefill_case(rng, H, KV, hd, ps, n, S)
    ck = jnp.asarray(rng.normal(size=(S, KV, hd)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(S, KV, hd)), jnp.float32)
    kq, vq, ks, vs = _quantized_pages(kp, vp)
    ref = paged_ring_prefill_ref(q, kq, vq, ck, cv, table, start, n_valid,
                                 window=window, k_scale=ks, v_scale=vs)
    out = paged_ring_prefill(q, kq, vq, ck, cv, table, start, n_valid,
                             window=window, k_scale=ks, v_scale=vs,
                             use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:n_valid]),
                               np.asarray(ref[:n_valid]), atol=2e-5)


def test_paged_ring_prefill_snapshot_semantics(rng):
    """The kernel must read the chunk's own K/V from the ride-along
    operands, never back through the (post-write) ring pages: poisoning
    the pages at the chunk's own write cells must not change output when
    the snapshot is passed."""
    H, KV, hd, ps, n = 4, 2, 32, 8, 3
    window, S, start, n_valid = 24, 16, 30, 13
    q, kp, vp, table = _prefill_case(rng, H, KV, hd, ps, n, S)
    ck = jnp.asarray(rng.normal(size=(S, KV, hd)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(S, KV, hd)), jnp.float32)
    base = [paged_ring_prefill(q, kp, vp, ck, cv, table, start, n_valid,
                               window=window, use_kernel=uk, interpret=True)
            for uk in (False, True)]
    np.testing.assert_allclose(np.asarray(base[1][:n_valid]),
                               np.asarray(base[0][:n_valid]), atol=2e-5)


MLA_PREFILL_CASES = [
    # (H, R, rp, ps, n, S, start, n_valid)
    (4, 32, 8, 8, 4, 16, 0, 16),
    (2, 16, 16, 4, 8, 8, 5, 6),
    (4, 32, 8, 16, 7, 256, 0, 100),   # multi-q-block + padded tail block
]


@pytest.mark.parametrize("H,R,rp,ps,n,S,start,n_valid", MLA_PREFILL_CASES)
def test_paged_mla_prefill_kernel_matches_ref(rng, H, R, rp, ps, n, S,
                                              start, n_valid):
    q_lat = jnp.asarray(rng.normal(size=(S, H, R)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(S, H, rp)), jnp.float32)
    ckv = jnp.asarray(rng.normal(size=(n + 1, ps, R)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(n + 1, ps, rp)), jnp.float32)
    table = jnp.arange(1, n + 1, dtype=jnp.int32)
    scale = (R + rp) ** -0.5
    ref = paged_mla_prefill_ref(q_lat, q_rope, ckv, kr, table, start,
                                n_valid, scale=scale)
    out = paged_mla_prefill(q_lat, q_rope, ckv, kr, table, start, n_valid,
                            scale=scale, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:n_valid]),
                               np.asarray(ref[:n_valid]), atol=2e-5)
