"""Paged-attention kernel family (repro.kernels.paged_attention).

The Pallas kernel (interpret mode) must match the pure-jnp oracle, the
oracle must match the slotted ring-cache decode path on identical K/V
(the invariant behind paged == slotted engine equivalence), and the
reserved trash page must be unreadable through any valid (table, length)
pair.  No hypothesis dependency — these always run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def _paged_case(rng, slots, H, KV, hd, ps, n, dtype):
    """Random pool + disjoint per-slot page tables + random lengths."""
    P = slots * n + 1                               # page 0 = trash
    q = jnp.asarray(rng.normal(size=(slots, H, hd)), dtype)
    kp = jnp.asarray(rng.normal(size=(P, ps, KV, hd)), dtype)
    vp = jnp.asarray(rng.normal(size=(P, ps, KV, hd)), dtype)
    lengths = np.asarray(rng.integers(1, n * ps + 1, size=slots), np.int32)
    table = np.zeros((slots, n), np.int32)
    pid = 1
    for s in range(slots):
        for i in range(-(-int(lengths[s]) // ps)):
            table[s, i] = pid
            pid += 1
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lengths)


PAGED_CASES = [
    # (slots, H, KV, hd, ps, n_table, dtype)
    (3, 4, 2, 32, 8, 4, jnp.float32),
    (2, 8, 8, 16, 4, 3, jnp.float32),     # MHA (G = 1)
    (1, 2, 1, 64, 16, 2, jnp.float32),    # MQA
    (4, 4, 2, 32, 8, 1, jnp.float32),     # single-page table
    (2, 4, 2, 32, 8, 3, jnp.bfloat16),    # bf16 i/o
]


@pytest.mark.parametrize("slots,H,KV,hd,ps,n,dtype", PAGED_CASES)
def test_paged_kernel_matches_ref(rng, slots, H, KV, hd, ps, n, dtype):
    q, kp, vp, table, lengths = _paged_case(rng, slots, H, KV, hd, ps, n,
                                            dtype)
    ref = paged_attention_ref(q, kp, vp, table, lengths)
    out = paged_attention(q, kp, vp, table, lengths, use_kernel=True,
                          interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_paged_ref_matches_ring_cache_decode(rng):
    """Pages cut from a contiguous ring cache score identically to
    ``decode_attend`` over that cache — the slotted/paged bridge."""
    from repro.models.attention import decode_attend
    H, KV, hd, ps, n = 4, 2, 32, 8, 3
    Lc = n * ps
    m = 13                                           # valid tokens
    k = jnp.asarray(rng.normal(size=(1, Lc, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, Lc, KV, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, 1, H, hd)), jnp.float32)
    pos = jnp.where(jnp.arange(Lc) < m, jnp.arange(Lc), -1).astype(jnp.int32)
    cache = {"k": k, "v": v, "pos": pos, "index": jnp.asarray(m, jnp.int32)}
    ring = decode_attend(q, cache)                   # [1, 1, H, hd]

    kp = jnp.concatenate([jnp.zeros((1, ps, KV, hd)),
                          k[0].reshape(n, ps, KV, hd)])   # page 0 = trash
    vp = jnp.concatenate([jnp.zeros((1, ps, KV, hd)),
                          v[0].reshape(n, ps, KV, hd)])
    table = jnp.asarray([[1, 2, 3]], jnp.int32)
    paged = paged_attention(q[:, 0], kp, vp, table,
                            jnp.asarray([m], jnp.int32))
    np.testing.assert_allclose(np.asarray(paged), np.asarray(ring[:, 0]),
                               atol=1e-5)


def test_trash_page_never_read(rng):
    """Garbage in page 0 (the write sink for empty slots and padding) must
    not leak into any slot's output."""
    q, kp, vp, table, lengths = _paged_case(rng, 3, 4, 2, 32, 8, 4,
                                            jnp.float32)
    base = paged_attention(q, kp, vp, table, lengths)
    kp2 = kp.at[0].set(1e4)
    vp2 = vp.at[0].set(-1e4)
    for use_kernel in (False, True):
        out = paged_attention(q, kp2, vp2, table, lengths,
                              use_kernel=use_kernel, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=2e-5)
