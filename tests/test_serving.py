"""Continuous-batching serving engine (repro.serving).

Three layers of guarantees:
  * scheduler packing invariants — FCFS order, admission control, priority
    ordering + preemption, no slot double-assignment;
  * KV-slot pool — insert/evict round-trip, eviction hygiene, exhaustion;
  * end-to-end — batched engine output is **token-identical** to an
    unbatched sequential decode of each request (the serving analogue of
    the paper's Fig. 7 equivalence test), on one device and under a mesh.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MeshConfig, ServeConfig, get_config
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.serving import (PagedKVCachePool, Request, Scheduler,
                           ServingEngine, ServingMetrics, SlotKVCachePool)
from repro.serving.metrics import percentile


def _req(rid, plen=4, max_new=4, priority=0, deadline=None):
    return Request(rid=rid, prompt=tuple(range(1, plen + 1)),
                   max_new_tokens=max_new, priority=priority,
                   deadline=deadline)


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


def _sequential_decode(cfg, params, prompt, n_new, cache_len):
    """Unbatched reference: prefill + single-sequence decode loop."""
    bundle = registry.build(cfg)
    prefill = jax.jit(bundle.serve_prefill_fn, static_argnames=("cache_len",))
    decode = jax.jit(bundle.decode_fn)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, state = prefill(params, toks, cache_len=cache_len)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, state = decode(params, jnp.asarray([[out[-1]]], jnp.int32),
                               state)
        out.append(int(jnp.argmax(logits[0])))
    return out


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

def test_scheduler_fcfs_order():
    s = Scheduler(ServeConfig(max_batch=4, max_prefills_per_step=2))
    for i in range(5):
        assert s.submit(_req(i))
    # chunked pops preserve arrival order, bounded by chunk AND free slots
    assert [r.rid for r in s.next_prefills(free_slots=4)] == [0, 1]
    assert [r.rid for r in s.next_prefills(free_slots=1)] == [2]
    assert [r.rid for r in s.next_prefills(free_slots=4)] == [3, 4]
    assert s.next_prefills(free_slots=4) == []


def test_scheduler_admission_control():
    s = Scheduler(ServeConfig(max_queue=2))
    assert s.submit(_req(0)) and s.submit(_req(1))
    assert not s.submit(_req(2))          # queue full -> rejected
    assert s.depth() == 2


def test_scheduler_priority_and_deadline_order():
    s = Scheduler(ServeConfig(policy="priority", max_prefills_per_step=8))
    s.submit(_req(0, priority=0))
    s.submit(_req(1, priority=5, deadline=20.0))
    s.submit(_req(2, priority=5, deadline=10.0))
    s.submit(_req(3, priority=5))         # no deadline sorts after deadlines
    order = [r.rid for r in s.next_prefills(free_slots=8)]
    assert order == [2, 1, 3, 0]


def test_scheduler_preemption_targets_lowest_priority():
    s = Scheduler(ServeConfig(policy="priority"))
    running = {0: _req(10, priority=1), 1: _req(11, priority=0),
               2: _req(12, priority=3)}
    s.submit(_req(20, priority=5))
    s.submit(_req(21, priority=2))
    victims = s.preemption(running)
    # two challengers outrank someone: rid20 evicts the weakest (rid11),
    # rid21 evicts the next weakest (rid10); rid12 (prio 3) survives.
    assert [(slot, v.rid) for slot, v in victims] == [(1, 11), (0, 10)]
    # equal priority never preempts (no livelock)
    s2 = Scheduler(ServeConfig(policy="priority"))
    s2.submit(_req(30, priority=1))
    assert s2.preemption({0: _req(31, priority=1)}) == []
    # fcfs never preempts
    s3 = Scheduler(ServeConfig(policy="fcfs"))
    s3.submit(_req(40, priority=9))
    assert s3.preemption({0: _req(41, priority=0)}) == []


def test_scheduler_requeued_preemptee_goes_first():
    s = Scheduler(ServeConfig(policy="priority", max_prefills_per_step=4))
    s.submit(_req(0, priority=1))
    victim = _req(99, priority=1)
    victim.tokens = [7, 8]
    s.requeue(victim)
    order = [r.rid for r in s.next_prefills(free_slots=4)]
    assert order == [99, 0]
    assert victim.resume_prompt() == victim.prompt + (7, 8)


def test_scheduler_requeue_counter_no_collision_keeps_order():
    """Regression: ``arrival_seq = -1 - preempted`` collided two
    once-preempted requests at -2 (sort ties broke arbitrarily) and let a
    twice-preempted request leapfrog an earlier once-preempted one."""
    s = Scheduler(ServeConfig(max_prefills_per_step=8))
    a, b = _req(0), _req(1)
    s.submit(a)
    s.submit(b)
    s.next_prefills(free_slots=8)                  # both running
    # one preemption round evicts least-urgent (latest arrival) first
    s.requeue(b)
    s.requeue(a)
    assert a.arrival_seq != b.arrival_seq          # collided at -2 before
    s.submit(_req(2))
    assert [r.rid for r in s.next_prefills(free_slots=8)] == [0, 1, 2]
    # the counter is strictly monotone across rounds: preemption count no
    # longer decides rank (the old scheme pinned seq at -1 - preempted, so
    # a twice-preempted request always outranked every once-preempted one)
    s.requeue(b)
    seq1 = b.arrival_seq
    (popped,) = s.next_prefills(free_slots=1)
    assert popped is b
    s.requeue(b)
    assert b.arrival_seq < seq1
    assert b.preempted == 3 and b.arrival_seq == -4   # 4th requeue overall


def test_scheduler_push_front_skips_preemption_bookkeeping():
    s = Scheduler(ServeConfig(max_prefills_per_step=8))
    s.submit(_req(0))
    (bounced,) = s.next_prefills(free_slots=1)
    s.push_front(bounced)                          # popped but not admitted
    assert bounced.preempted == 0
    s.submit(_req(1))
    assert [r.rid for r in s.next_prefills(free_slots=8)] == [0, 1]


# ---------------------------------------------------------------------------
# KV slot pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params


def test_pool_no_double_assignment(dense_setup):
    cfg, bundle, _ = dense_setup
    pool = SlotKVCachePool(3, lambda: bundle.init_decode_state(1, 16))
    slots = [pool.alloc(rid) for rid in (0, 1, 2)]
    assert sorted(slots) == [0, 1, 2]          # all distinct
    assert pool.alloc(3) is None               # exhausted -> None
    rid = pool.evict(slots[1])
    assert rid == 1 and pool.free_slots == 1
    assert pool.alloc(4) == slots[1]           # freed slot is reusable


def test_pool_insert_evict_roundtrip(dense_setup):
    cfg, bundle, params = dense_setup
    cap = 24
    pool = SlotKVCachePool(2, lambda: bundle.init_decode_state(1, cap))
    prompt = np.arange(1, 8, dtype=np.int32)[None]
    _, state = jax.jit(bundle.serve_prefill_fn,
                       static_argnames=("cache_len",))(
        params, jnp.asarray(prompt), cache_len=cap)
    slot = pool.insert(rid=7, one_state=state)
    assert slot is not None and pool.owner[slot] == 7
    back = pool.read(slot)
    jax.tree.map(np.testing.assert_array_equal, back,
                 jax.tree.map(np.asarray, state))
    # eviction blanks the slot (no stale K/V for the next tenant)
    pool.evict(slot)
    blank = bundle.init_decode_state(1, cap)
    jax.tree.map(np.testing.assert_array_equal, pool.read(slot),
                 jax.tree.map(np.asarray, blank))


# ---------------------------------------------------------------------------
# Paged KV pool
# ---------------------------------------------------------------------------

def test_paged_pool_allocator_properties(dense_setup):
    """Pages freed == pages allocated, no page aliasing across slots, the
    trash page is never handed out, and pages grow lazily with ``pos``."""
    cfg, bundle, params = dense_setup
    pool = PagedKVCachePool(3, 8, 32, lambda: bundle.init_decode_state(1, 8))
    assert pool.padded_len == 32 and pool.num_pages == 3 * 4 + 1
    prefill = jax.jit(bundle.serve_prefill_fn, static_argnames=("cache_len",))

    def admit(rid, plen):
        toks = jnp.asarray(np.arange(1, plen + 1, dtype=np.int32)[None])
        _, st = prefill(params, toks, cache_len=pool.padded_len)
        return pool.insert(rid, st, n_tokens=plen)

    s0 = admit(0, 5)                        # 1 page
    s1 = admit(1, 17)                       # 3 pages
    assert len(pool.held[s0]) == 1 and len(pool.held[s1]) == 3
    assert 0 not in pool.held[s0] + pool.held[s1]          # trash reserved
    assert not set(pool.held[s0]) & set(pool.held[s1])     # no aliasing
    assert pool.kv_bytes_held() == 4 * pool.page_bytes
    assert pool.kv_bytes_held() < pool.kv_bytes_slotted()
    # lazy growth: slot 0 needs a second page only once pos crosses 8
    for expect_pages in (1, 1, 1, 2):
        assert pool.ensure_decode_capacity() == []
        assert len(pool.held[s0]) == expect_pages
        pool.advance()
    assert int(pool.pos[s0]) == 9
    assert pool.pages_allocated == 1 + 3 + 1
    # eviction returns every page and zeroes the host view
    pool.evict(s0)
    pool.evict(s1)
    assert pool.pages_held == 0
    assert pool.pages_freed == pool.pages_allocated == 5
    assert (pool.tables == 0).all() and (pool.pos == 0).all()
    assert pool.free_slots == 3


def test_paged_pool_exhaustion_reports_starved(dense_setup):
    cfg, bundle, params = dense_setup
    # 3 usable pages (+ trash) for two slots of up to 2 pages each
    pool = PagedKVCachePool(2, 8, 16, lambda: bundle.init_decode_state(1, 8),
                            num_pages=4)
    prefill = jax.jit(bundle.serve_prefill_fn, static_argnames=("cache_len",))
    toks = jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])
    _, st = prefill(params, toks, cache_len=pool.padded_len)
    assert pool.insert(0, st, n_tokens=8) is not None   # 1 page each
    assert pool.insert(1, st, n_tokens=8) is not None
    assert pool.can_admit(8) is False       # 1 page left, no slot anyway
    # both slots sit on a page boundary (pos == 8): each wants a 2nd page,
    # but only one page remains — slot 1 starves
    starved = pool.ensure_decode_capacity()
    assert starved == [1] and len(pool.held[0]) == 2
    pool.evict(0)                           # freeing one unblocks the other
    assert pool.ensure_decode_capacity() == []
    assert len(pool.held[1]) == 2


def test_paged_pool_rejects_undersized(dense_setup):
    cfg, bundle, _ = dense_setup
    with pytest.raises(ValueError, match="cannot hold one request"):
        PagedKVCachePool(2, 8, 32, lambda: bundle.init_decode_state(1, 8),
                         num_pages=3)


def test_serve_config_validates_at_construction():
    """Bad knob combinations fail with a clear ValueError the moment the
    config exists — not deep inside PagedKVCachePool or the engine loop."""
    with pytest.raises(ValueError, match="kv_layout"):
        ServeConfig(kv_layout="ragged")
    with pytest.raises(ValueError, match="policy"):
        ServeConfig(policy="edf")
    with pytest.raises(ValueError, match="page_size"):
        ServeConfig(page_size=0)
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    # page_size/num_pages/max_seq_len consistency
    with pytest.raises(ValueError, match="trash page"):
        ServeConfig(max_seq_len=64, page_size=8, num_pages=4)
    with pytest.raises(ValueError, match="page would never fill"):
        ServeConfig(max_seq_len=8, page_size=16)
    ServeConfig(max_seq_len=64, page_size=8, num_pages=9).validate()
    ServeConfig().validate()


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_metrics_preemption_clears_itl_baseline():
    """Regression: the victim's last-token timestamp survived eviction, so
    its first token after re-prefill recorded eviction + queueing time as
    one giant inter-token latency sample."""
    t = [0.0]
    m = ServingMetrics(clock=lambda: t[0])
    m.record_submit(0)
    t[0] = 1.0
    m.record_first_token(0)
    t[0] = 1.5
    m.record_token(0)                       # ITL 0.5
    m.record_preemption(0)                  # evicted: baseline dropped
    t[0] = 9.0
    m.record_token(0)                       # resume: NO 7.5s sample
    t[0] = 9.5
    m.record_token(0)                       # ITL 0.5
    assert m.preemptions == 1
    assert m.itl == [0.5, 0.5]
    # the argless variant still counts (no rid to clear)
    m.record_preemption()
    assert m.preemptions == 2


def test_percentile_ceil_nearest_rank():
    """Regression: ``round(0.5) == 0`` (banker's rounding) biased the
    nearest-rank percentile low/high on small samples."""
    assert percentile([1, 2, 3, 4], 50) == 2    # banker's rank gave 3
    assert percentile([1, 2], 50) == 1
    assert percentile([1, 2, 3], 50) == 2
    assert percentile(list(range(1, 101)), 99) == 99
    assert percentile(list(range(1, 101)), 100) == 100
    assert percentile([7], 99) == 7
    assert percentile([], 50) == 0.0


def test_metrics_deterministic_clock():
    t = [0.0]
    m = ServingMetrics(clock=lambda: t[0])
    m.record_submit(0)
    t[0] = 0.5
    m.record_first_token(0)                    # TTFT = 0.5
    t[0] = 0.7
    m.record_token(0)                          # ITL = 0.2
    t[0] = 1.0
    m.record_token(0)                          # ITL = 0.3
    m.record_completion(0)
    s = m.summary()
    assert s["ttft_p50_s"] == pytest.approx(0.5)
    assert s["itl_p50_s"] == pytest.approx(0.2) or \
        s["itl_p50_s"] == pytest.approx(0.3)
    assert s["tokens_out"] == 3 and s["completed"] == 1
    assert s["tokens_per_sec"] == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# End-to-end: batched engine == sequential decode (Fig. 7 analogue)
# ---------------------------------------------------------------------------

def test_engine_matches_sequential_decode(dense_setup):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=3, max_seq_len=48, max_new_tokens=6,
                       max_prefills_per_step=2, decode_steps=2)
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5, 9, 11, 6])
    events = []
    outs = eng.generate(prompts, 6,
                        stream=lambda r, t, d: events.append((r, t, d)))
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, params, p, 6, scfg.max_seq_len)
    # every request finished, streamed exactly its tokens, in order
    assert eng.metrics.summary()["completed"] == len(prompts)
    assert not eng.busy
    for rid, toks in enumerate(outs):
        assert [t for r, t, _ in events if r == rid] == toks
    assert sum(d for _, _, d in events) == len(prompts)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
def test_engine_matches_sequential_decode_families(arch):
    cfg = get_config(arch, smoke=True)
    scfg = ServeConfig(max_batch=2, max_seq_len=24, max_new_tokens=4,
                       decode_steps=3)
    eng = ServingEngine(cfg, scfg, seed=0)
    params = eng.params
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, cfg.vocab_size, [6, 9, 5])
    outs = eng.generate(prompts, 4)
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, params, p, 4, scfg.max_seq_len)


@pytest.mark.parametrize("layout", ["paged", "slotted"])
def test_engine_mesh_matches_single_device(dense_setup, layout):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=4, max_seq_len=40, max_new_tokens=4,
                       decode_steps=2, kv_layout=layout, page_size=8)
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, cfg.vocab_size, [7, 11, 6, 9, 8])
    # conftest forces 8 host devices: 2-way data (slots) x 2-way model (TP)
    mesh_cfg = MeshConfig(shape=(2, 2), axis_names=("data", "model"))
    out_mesh = ServingEngine(cfg, scfg, params=params,
                             mesh_cfg=mesh_cfg).generate(prompts, 4)
    out_single = ServingEngine(cfg, scfg, params=params).generate(prompts, 4)
    assert out_mesh == out_single


def test_engine_paged_matches_slotted(dense_setup):
    """Tentpole equivalence: the paged pool + paged decode emits exactly the
    slotted pool's greedy tokens, while holding KV for the tokens actually
    cached instead of the full ``max_batch x max_seq_len`` wall."""
    cfg, _, params = dense_setup
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5, 9])
    base = ServeConfig(max_batch=2, max_seq_len=40, max_new_tokens=5,
                       max_prefills_per_step=2, decode_steps=2, page_size=8)
    ep = ServingEngine(cfg, base.replace(kv_layout="paged"), params=params)
    assert ep.paged
    out_p = ep.generate(prompts, 5)
    es = ServingEngine(cfg, base.replace(kv_layout="slotted"), params=params)
    assert not es.paged
    assert out_p == es.generate(prompts, 5)
    sp, ss = ep.metrics.summary(), es.metrics.summary()
    # pages held scale with live tokens; the slotted pool pins its ceiling
    assert 0 < sp["kv_bytes_peak"] < sp["kv_bytes_slotted"]
    assert ss["kv_bytes_peak"] == ss["kv_bytes_slotted"]
    assert ep.pool.pages_allocated == ep.pool.pages_freed
    assert ep.pool.pages_held == 0


def test_engine_paged_page_pressure_preempts_and_recovers(dense_setup):
    """An under-provisioned page pool (oversubscription) forces preemption
    on decode-time growth; resumed requests still emit identical tokens."""
    cfg, _, params = dense_setup
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, cfg.vocab_size, [14, 15])
    scfg = ServeConfig(max_batch=2, max_seq_len=32, max_new_tokens=12,
                       decode_steps=2, kv_layout="paged", page_size=4,
                       num_pages=12)       # worst case would need 17
    eng = ServingEngine(cfg, scfg, params=params)
    outs = eng.generate(prompts, 12)
    assert eng.metrics.preemptions >= 1
    assert eng.metrics.summary()["completed"] == 2
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, params, p, 12,
                                         eng.pool.padded_len)


def test_engine_paged_admission_bounce_drops_no_request(dense_setup):
    """Regression: when pages (not slots) gate admission, every popped-but-
    unplaceable request must return to the queue — a bounced prefill chunk
    once abandoned its tail requests entirely (neither queued nor pooled)."""
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=3, max_seq_len=16, max_new_tokens=5,
                       max_prefills_per_step=2, decode_steps=1, kv_layout="paged",
                       page_size=4, num_pages=5)     # 4 usable pages
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(13)
    # r0 takes 3 of 4 pages; the [r1, r2] chunk then bounces on r1
    prompts = _prompts(rng, cfg.vocab_size, [11, 8, 4])
    outs = eng.generate(prompts, 5)
    assert eng.metrics.summary()["completed"] == 3
    assert len(eng.results) == 3 and not eng.busy
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, params, p, 5,
                                         eng.pool.padded_len)


def test_engine_paged_priority_preempts_on_page_pressure(dense_setup):
    """Regression: priority preemption used to require free_slots == 0, so
    under the paged layout a high-priority waiter blocked on *pages* (slots
    free) would wait out the low-priority request instead of preempting."""
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=2, max_seq_len=16, max_new_tokens=4,
                       policy="priority", max_prefills_per_step=1, decode_steps=1,
                       kv_layout="paged", page_size=4, num_pages=5)
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(17)
    low = eng.submit(list(rng.integers(0, cfg.vocab_size, (11,))),
                     max_new_tokens=4, priority=0)
    eng.step()                     # low holds 3 of 4 pages; a slot is free
    assert eng.pool.free_slots == 1
    high = eng.submit(list(rng.integers(0, cfg.vocab_size, (8,))),
                      max_new_tokens=4, priority=5)
    out = eng.run()
    assert eng.metrics.preemptions >= 1
    assert eng.requests[low].preempted >= 1
    assert len(out[high]) == 4 and len(out[low]) == 4
    assert eng.metrics.summary()["completed"] == 2


def test_engine_kv_layout_paged_rejected_for_recurrent():
    cfg = get_config("rwkv6-1.6b", smoke=True)
    with pytest.raises(ValueError, match="no paged decode"):
        ServingEngine(cfg, ServeConfig(max_batch=1, max_seq_len=16,
                                       kv_layout="paged"))
    # "auto" quietly falls back to the slotted pool
    eng = ServingEngine(cfg, ServeConfig(max_batch=1, max_seq_len=16))
    assert not eng.paged


def test_engine_preemption_itl_excludes_gap(dense_setup):
    """End-to-end ITL regression: with a ticking clock, the victim's resume
    must not record the whole eviction->re-prefill span as one sample."""
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=1, max_seq_len=40, max_new_tokens=8,
                       policy="priority", decode_steps=1, max_prefills_per_step=1)
    ticks = itertools.count()
    eng = ServingEngine(cfg, scfg, params=params,
                        clock=lambda: float(next(ticks)))
    rng = np.random.default_rng(3)
    eng.submit(list(rng.integers(0, cfg.vocab_size, (6,))),
               max_new_tokens=8, priority=0)
    eng.step()                                 # low occupies the only slot
    eng.submit(list(rng.integers(0, cfg.vocab_size, (5,))),
               max_new_tokens=3, priority=5)
    eng.run()
    assert eng.metrics.preemptions >= 1
    # every now() call ticks once; adjacent same-request tokens are 1-2
    # ticks apart, while the preemption gap spans the high-priority
    # request's whole lifetime (>= 5 ticks) — it must not appear in itl
    assert eng.metrics.itl and max(eng.metrics.itl) <= 3.0


def test_engine_priority_preemption_end_to_end(dense_setup):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=1, max_seq_len=40, max_new_tokens=8,
                       policy="priority", decode_steps=1, max_prefills_per_step=1)
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(3)
    low = eng.submit(list(rng.integers(0, cfg.vocab_size, (6,))),
                     max_new_tokens=8, priority=0)
    eng.step()                                 # low occupies the only slot
    assert eng.pool.free_slots == 0
    high = eng.submit(list(rng.integers(0, cfg.vocab_size, (5,))),
                      max_new_tokens=3, priority=5)
    out = eng.run()
    assert eng.metrics.preemptions >= 1
    assert eng.requests[low].preempted >= 1
    assert len(out[high]) == 3 and len(out[low]) == 8
    # the high-priority request finished before the preempted one resumed:
    # its completion evicted the slot the victim later reclaimed
    assert eng.metrics.summary()["completed"] == 2


def test_engine_admission_queue_full(dense_setup):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=1, max_queue=2, max_seq_len=32,
                       max_new_tokens=4)
    eng = ServingEngine(cfg, scfg, params=params)
    assert eng.submit([1, 2, 3]) is not None
    assert eng.submit([1, 2, 3]) is not None
    assert eng.submit([1, 2, 3]) is None       # shed load
    assert eng.metrics.rejected == 1


def test_engine_rejects_oversized_request(dense_setup):
    cfg, _, params = dense_setup
    eng = ServingEngine(cfg, ServeConfig(max_batch=1, max_seq_len=16),
                        params=params)
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(list(range(1, 15)), max_new_tokens=10)


def test_unserved_families_raise():
    cfg = get_config("whisper-tiny", smoke=True)
    with pytest.raises(ValueError, match="no serving"):
        ServingEngine(cfg, ServeConfig(max_batch=1, max_seq_len=16))
