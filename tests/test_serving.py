"""Continuous-batching serving engine (repro.serving).

Three layers of guarantees:
  * scheduler packing invariants — FCFS order, admission control, priority
    ordering + preemption, no slot double-assignment;
  * KV-slot pool — insert/evict round-trip, eviction hygiene, exhaustion;
  * end-to-end — batched engine output is **token-identical** to an
    unbatched sequential decode of each request (the serving analogue of
    the paper's Fig. 7 equivalence test), on one device and under a mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MeshConfig, ServeConfig, get_config
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.serving import (Request, Scheduler, ServingEngine, ServingMetrics,
                           SlotKVCachePool)


def _req(rid, plen=4, max_new=4, priority=0, deadline=None):
    return Request(rid=rid, prompt=tuple(range(1, plen + 1)),
                   max_new_tokens=max_new, priority=priority,
                   deadline=deadline)


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


def _sequential_decode(cfg, params, prompt, n_new, cache_len):
    """Unbatched reference: prefill + single-sequence decode loop."""
    bundle = registry.build(cfg)
    prefill = jax.jit(bundle.serve_prefill_fn, static_argnames=("cache_len",))
    decode = jax.jit(bundle.decode_fn)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, state = prefill(params, toks, cache_len=cache_len)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, state = decode(params, jnp.asarray([[out[-1]]], jnp.int32),
                               state)
        out.append(int(jnp.argmax(logits[0])))
    return out


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

def test_scheduler_fcfs_order():
    s = Scheduler(ServeConfig(max_batch=4, prefill_chunk=2))
    for i in range(5):
        assert s.submit(_req(i))
    # chunked pops preserve arrival order, bounded by chunk AND free slots
    assert [r.rid for r in s.next_prefills(free_slots=4)] == [0, 1]
    assert [r.rid for r in s.next_prefills(free_slots=1)] == [2]
    assert [r.rid for r in s.next_prefills(free_slots=4)] == [3, 4]
    assert s.next_prefills(free_slots=4) == []


def test_scheduler_admission_control():
    s = Scheduler(ServeConfig(max_queue=2))
    assert s.submit(_req(0)) and s.submit(_req(1))
    assert not s.submit(_req(2))          # queue full -> rejected
    assert s.depth() == 2


def test_scheduler_priority_and_deadline_order():
    s = Scheduler(ServeConfig(policy="priority", prefill_chunk=8))
    s.submit(_req(0, priority=0))
    s.submit(_req(1, priority=5, deadline=20.0))
    s.submit(_req(2, priority=5, deadline=10.0))
    s.submit(_req(3, priority=5))         # no deadline sorts after deadlines
    order = [r.rid for r in s.next_prefills(free_slots=8)]
    assert order == [2, 1, 3, 0]


def test_scheduler_preemption_targets_lowest_priority():
    s = Scheduler(ServeConfig(policy="priority"))
    running = {0: _req(10, priority=1), 1: _req(11, priority=0),
               2: _req(12, priority=3)}
    s.submit(_req(20, priority=5))
    s.submit(_req(21, priority=2))
    victims = s.preemption(running)
    # two challengers outrank someone: rid20 evicts the weakest (rid11),
    # rid21 evicts the next weakest (rid10); rid12 (prio 3) survives.
    assert [(slot, v.rid) for slot, v in victims] == [(1, 11), (0, 10)]
    # equal priority never preempts (no livelock)
    s2 = Scheduler(ServeConfig(policy="priority"))
    s2.submit(_req(30, priority=1))
    assert s2.preemption({0: _req(31, priority=1)}) == []
    # fcfs never preempts
    s3 = Scheduler(ServeConfig(policy="fcfs"))
    s3.submit(_req(40, priority=9))
    assert s3.preemption({0: _req(41, priority=0)}) == []


def test_scheduler_requeued_preemptee_goes_first():
    s = Scheduler(ServeConfig(policy="priority", prefill_chunk=4))
    s.submit(_req(0, priority=1))
    victim = _req(99, priority=1)
    victim.tokens = [7, 8]
    s.requeue(victim)
    order = [r.rid for r in s.next_prefills(free_slots=4)]
    assert order == [99, 0]
    assert victim.resume_prompt() == victim.prompt + (7, 8)


# ---------------------------------------------------------------------------
# KV slot pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return cfg, bundle, params


def test_pool_no_double_assignment(dense_setup):
    cfg, bundle, _ = dense_setup
    pool = SlotKVCachePool(3, lambda: bundle.init_decode_state(1, 16))
    slots = [pool.alloc(rid) for rid in (0, 1, 2)]
    assert sorted(slots) == [0, 1, 2]          # all distinct
    assert pool.alloc(3) is None               # exhausted -> None
    rid = pool.evict(slots[1])
    assert rid == 1 and pool.free_slots == 1
    assert pool.alloc(4) == slots[1]           # freed slot is reusable


def test_pool_insert_evict_roundtrip(dense_setup):
    cfg, bundle, params = dense_setup
    cap = 24
    pool = SlotKVCachePool(2, lambda: bundle.init_decode_state(1, cap))
    prompt = np.arange(1, 8, dtype=np.int32)[None]
    _, state = jax.jit(bundle.serve_prefill_fn,
                       static_argnames=("cache_len",))(
        params, jnp.asarray(prompt), cache_len=cap)
    slot = pool.insert(rid=7, one_state=state)
    assert slot is not None and pool.owner[slot] == 7
    back = pool.read(slot)
    jax.tree.map(np.testing.assert_array_equal, back,
                 jax.tree.map(np.asarray, state))
    # eviction blanks the slot (no stale K/V for the next tenant)
    pool.evict(slot)
    blank = bundle.init_decode_state(1, cap)
    jax.tree.map(np.testing.assert_array_equal, pool.read(slot),
                 jax.tree.map(np.asarray, blank))


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_metrics_deterministic_clock():
    t = [0.0]
    m = ServingMetrics(clock=lambda: t[0])
    m.record_submit(0)
    t[0] = 0.5
    m.record_first_token(0)                    # TTFT = 0.5
    t[0] = 0.7
    m.record_token(0)                          # ITL = 0.2
    t[0] = 1.0
    m.record_token(0)                          # ITL = 0.3
    m.record_completion(0)
    s = m.summary()
    assert s["ttft_p50_s"] == pytest.approx(0.5)
    assert s["itl_p50_s"] == pytest.approx(0.2) or \
        s["itl_p50_s"] == pytest.approx(0.3)
    assert s["tokens_out"] == 3 and s["completed"] == 1
    assert s["tokens_per_sec"] == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# End-to-end: batched engine == sequential decode (Fig. 7 analogue)
# ---------------------------------------------------------------------------

def test_engine_matches_sequential_decode(dense_setup):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=3, max_seq_len=48, max_new_tokens=6,
                       prefill_chunk=2, decode_steps=2)
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5, 9, 11, 6])
    events = []
    outs = eng.generate(prompts, 6,
                        stream=lambda r, t, d: events.append((r, t, d)))
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, params, p, 6, scfg.max_seq_len)
    # every request finished, streamed exactly its tokens, in order
    assert eng.metrics.summary()["completed"] == len(prompts)
    assert not eng.busy
    for rid, toks in enumerate(outs):
        assert [t for r, t, _ in events if r == rid] == toks
    assert sum(d for _, _, d in events) == len(prompts)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
def test_engine_matches_sequential_decode_families(arch):
    cfg = get_config(arch, smoke=True)
    scfg = ServeConfig(max_batch=2, max_seq_len=24, max_new_tokens=4,
                       decode_steps=3)
    eng = ServingEngine(cfg, scfg, seed=0)
    params = eng.params
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, cfg.vocab_size, [6, 9, 5])
    outs = eng.generate(prompts, 4)
    for p, got in zip(prompts, outs):
        assert got == _sequential_decode(cfg, params, p, 4, scfg.max_seq_len)


def test_engine_mesh_matches_single_device(dense_setup):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=4, max_seq_len=40, max_new_tokens=4,
                       decode_steps=2)
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, cfg.vocab_size, [7, 11, 6, 9, 8])
    # conftest forces 8 host devices: 2-way data (slots) x 2-way model (TP)
    mesh_cfg = MeshConfig(shape=(2, 2), axis_names=("data", "model"))
    out_mesh = ServingEngine(cfg, scfg, params=params,
                             mesh_cfg=mesh_cfg).generate(prompts, 4)
    out_single = ServingEngine(cfg, scfg, params=params).generate(prompts, 4)
    assert out_mesh == out_single


def test_engine_priority_preemption_end_to_end(dense_setup):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=1, max_seq_len=40, max_new_tokens=8,
                       policy="priority", decode_steps=1, prefill_chunk=1)
    eng = ServingEngine(cfg, scfg, params=params)
    rng = np.random.default_rng(3)
    low = eng.submit(list(rng.integers(0, cfg.vocab_size, (6,))),
                     max_new_tokens=8, priority=0)
    eng.step()                                 # low occupies the only slot
    assert eng.pool.free_slots == 0
    high = eng.submit(list(rng.integers(0, cfg.vocab_size, (5,))),
                      max_new_tokens=3, priority=5)
    out = eng.run()
    assert eng.metrics.preemptions >= 1
    assert eng.requests[low].preempted >= 1
    assert len(out[high]) == 3 and len(out[low]) == 8
    # the high-priority request finished before the preempted one resumed:
    # its completion evicted the slot the victim later reclaimed
    assert eng.metrics.summary()["completed"] == 2


def test_engine_admission_queue_full(dense_setup):
    cfg, _, params = dense_setup
    scfg = ServeConfig(max_batch=1, max_queue=2, max_seq_len=32,
                       max_new_tokens=4)
    eng = ServingEngine(cfg, scfg, params=params)
    assert eng.submit([1, 2, 3]) is not None
    assert eng.submit([1, 2, 3]) is not None
    assert eng.submit([1, 2, 3]) is None       # shed load
    assert eng.metrics.rejected == 1


def test_engine_rejects_oversized_request(dense_setup):
    cfg, _, params = dense_setup
    eng = ServingEngine(cfg, ServeConfig(max_batch=1, max_seq_len=16),
                        params=params)
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(list(range(1, 15)), max_new_tokens=10)


def test_unserved_families_raise():
    cfg = get_config("whisper-tiny", smoke=True)
    with pytest.raises(ValueError, match="no serving"):
        ServingEngine(cfg, ServeConfig(max_batch=1, max_seq_len=16))
