"""Int8 quantized-KV paged layout (ServeConfig.kv_dtype, PR 10).

Four layers of guarantees:
  * layout seam — ``layout_for(cfg, kv_dtype="int8")`` /
    ``quantized_layout`` emit int8 data leaves with per-row ``*_scale``
    leaves; MLA latent and slotted-only families are rejected with errors
    naming both knobs (the ``check_window`` validation pattern);
  * quantizer purity — ``quantize_kv`` is the single quantizer and a pure
    function of the written row (dequant with the stored bf16 scale
    reconstructs exactly what was quantized), which is what makes every
    identity below hold;
  * token identity *within* the quantized world — int8 paged kernel-on vs
    kernel-off, warm vs cold (prefix cache), and under a 2x2 data x model
    mesh are exactly token-identical: quantization happens once on write,
    so every path reads the same page bytes;
  * tolerance *across* worlds — int8 paged vs the fp32 slotted oracle is
    an approximation: top-1 agreement >= 0.95 over short greedy decodes,
    and the quantized pool's bytes land under 0.30x the fp32 page.
"""
import numpy as np
import pytest

from repro.configs import MeshConfig, ServeConfig, get_config
from repro.serving import ServingEngine, layout_for
from repro.serving.layouts import (KV_DTYPES, SCALE_SUFFIX, quantize_kv,
                                   quantized_layout)

#: per-head paged archs (full + ring geometries) — MLA is excluded by
#: design and asserted below
ARCHS = {
    "full": "qwen2.5-14b",
    "swa": "mixtral-8x22b",
}


def _cfg(kind):
    return get_config(ARCHS[kind], smoke=True)


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


def _engine(cfg, params=None, mesh_cfg=None, **kw):
    base = dict(max_batch=2, max_seq_len=40, max_new_tokens=5,
                decode_steps=2, kv_layout="paged", kv_dtype="int8",
                page_size=4)
    base.update(kw)
    return ServingEngine(cfg, ServeConfig(**base), params=params,
                         mesh_cfg=mesh_cfg)


# ---------------------------------------------------------------------------
# Layout seam + validation
# ---------------------------------------------------------------------------

def test_layout_for_emits_quantized_variants():
    for kind in ARCHS:
        lay = layout_for(_cfg(kind), kv_dtype="int8")
        assert lay.quantized and lay.kv_dtype == "int8"
        assert lay.data_leaves == ("k", "v")
        assert set(lay.leaves) == {"k", "v", "k_scale", "v_scale"}
        base = layout_for(_cfg(kind))
        assert not base.quantized and base.leaves == ("k", "v")
        assert quantized_layout(base, "fp32") is base
        assert quantized_layout(base, "int8") == lay


def test_int8_mla_rejected_naming_both_knobs():
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    with pytest.raises(ValueError) as e:
        layout_for(cfg, kv_dtype="int8")
    assert "kv_dtype" in str(e.value) and "mla" in str(e.value)
    # same error surfaces at engine construction
    with pytest.raises(ValueError, match="mla"):
        _engine(cfg)


def test_serve_config_validates_kv_dtype():
    assert ServeConfig().kv_dtype == "fp32"
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeConfig(kv_dtype="fp8")
    with pytest.raises(ValueError, match="slotted"):
        ServeConfig(kv_dtype="int8", kv_layout="slotted")
    # auto-resolved slotted (recurrent family, no KVLayout) fails at the
    # engine with the slotted-only error
    with pytest.raises(ValueError, match="slotted-only"):
        ServingEngine(get_config("rwkv6-1.6b", smoke=True),
                      ServeConfig(kv_dtype="int8", max_batch=2,
                                  max_seq_len=40))
    assert "int8" in KV_DTYPES and "fp32" in KV_DTYPES


def test_quantize_kv_pure_roundtrip():
    """q is int8 in [-127, 127], the scale reconstructs the row within
    half a quantization step, and re-quantizing the dequantized row is a
    fixed point — the purity the identity matrix rests on."""
    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(3, 8, 2, 16)) * 5, np.float32)
    x[0, 0, 0] = 0.0                           # all-zero row: scale = 1
    q, s = quantize_kv(x)
    q, s = np.asarray(q), np.asarray(s)
    assert q.dtype == np.int8 and str(s.dtype) == "bfloat16"
    assert q.min() >= -127 and q.max() <= 127
    deq = q.astype(np.float32) * s.astype(np.float32)[..., None]
    step = s.astype(np.float32)[..., None]
    assert np.all(np.abs(deq - x) <= 0.5001 * step)
    q2, s2 = quantize_kv(deq)
    np.testing.assert_array_equal(np.asarray(q2), q)
    np.testing.assert_array_equal(np.asarray(s2), s)


# ---------------------------------------------------------------------------
# Exact identity within the quantized world
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_int8_kernel_on_off_and_warm_cold_identity(kind):
    """Quantized paged serving is token-identical kernel-on vs kernel-off
    and warm vs cold: pages are quantized once on write, so the gather
    oracle and the fused Pallas kernels read the same bytes."""
    cfg = _cfg(kind)
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5, 9])
    prompts.append(list(prompts[0]))          # warm-in-batch
    eng = {}
    out = {}
    for use_pallas in (False, True):
        e = _engine(cfg, params=eng.get(False) and eng[False].params,
                    use_pallas=use_pallas)
        assert e.paged and e.layout.quantized
        assert "k" + SCALE_SUFFIX in e.pool.pages
        assert e.paged_kernel == use_pallas
        eng[use_pallas], out[use_pallas] = e, e.generate(prompts, 5)
    assert out[False] == out[True]
    # warm pass: every block cached; quantized pages re-read, not re-made
    e = eng[True]
    e.metrics.reset()
    e.results.clear()
    assert e.generate(prompts, 5) == out[True]
    assert e.metrics.prefix_hit_tokens > 0
    assert e.pool.pages_held == 0
    assert e.pool.pages_allocated == e.pool.pages_freed
    # the int8 pool's peak undercuts even the fp32 slotted wall
    sp = e.metrics.summary()
    assert 0 < sp["kv_bytes_peak"] <= sp["kv_bytes_slotted"]


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["gather", "kernel"])
@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_int8_identity_under_mesh(kind, use_pallas):
    """2x2 data x model mesh (conftest forces 8 host devices): sharded
    quantized pages (scale leaves shard with their data leaves) emit the
    single-device tokens exactly."""
    cfg = _cfg(kind)
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, cfg.vocab_size, [7, 11, 6, 9])
    mesh_cfg = MeshConfig(shape=(2, 2), axis_names=("data", "model"))
    em = _engine(cfg, mesh_cfg=mesh_cfg, max_batch=4,
                 use_pallas=use_pallas)
    out_mesh = em.generate(prompts, 4)
    out_single = _engine(cfg, params=em.params,
                         max_batch=4).generate(prompts, 4)
    assert out_mesh == out_single
    assert em.metrics.summary()["completed"] == len(prompts)


# ---------------------------------------------------------------------------
# Tolerance across worlds + memory accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_int8_tracks_fp32_slotted_oracle(kind):
    """Across the quantization boundary identity is NOT exact — int8 is
    an approximation.  Over short greedy decodes the per-position top-1
    agreement with the fp32 slotted oracle must stay >= 0.95."""
    cfg = _cfg(kind)
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5, 9, 11, 6, 8, 10])
    e8 = _engine(cfg, max_new_tokens=8)
    out8 = e8.generate(prompts, 8)
    es = ServingEngine(cfg, ServeConfig(max_batch=2, max_seq_len=40,
                                        max_new_tokens=8, decode_steps=2,
                                        kv_layout="slotted"),
                       params=e8.params)
    outs = es.generate(prompts, 8)
    match = sum(a == b for p8, ps_ in zip(out8, outs)
                for a, b in zip(p8, ps_))
    total = sum(len(p) for p in out8)
    assert total == 8 * len(prompts)
    assert match / total >= 0.95, f"top-1 agreement {match}/{total}"


def test_int8_page_bytes_under_budget():
    """An int8 page (int8 rows + bf16 scales) must cost <= 0.30x its fp32
    equivalent — the acceptance bar behind ``kv_bytes_peak``'s ~4x drop
    (the hd=16 smoke shapes sit at (16 + 2) / 64 ~ 0.281)."""
    for kind in sorted(ARCHS):
        pool = _engine(_cfg(kind)).pool
        assert pool.page_bytes < pool.page_bytes_fp32
        assert pool.page_bytes / pool.page_bytes_fp32 <= 0.30, kind
        # fp32 engines report a ratio of exactly 1
        fp = _engine(_cfg(kind), kv_dtype="fp32").pool
        assert fp.page_bytes == fp.page_bytes_fp32


# ---------------------------------------------------------------------------
# Session hygiene on dtype switches
# ---------------------------------------------------------------------------

def test_session_kv_dtype_switch_drops_stale_engine():
    from repro import api
    sess = api.load("qwen2.5-14b", smoke=True, num_layers=2)
    prompt = list(range(4, 20))
    out8 = sess.generate(prompt, max_new=4, kv_layout="paged",
                         kv_dtype="int8")
    eng8 = sess.engine
    assert eng8.layout.quantized
    out32 = sess.generate(prompt, max_new=4, kv_layout="paged",
                          kv_dtype="fp32")
    assert eng8 not in sess._engines.values()
    assert not eng8.pool._index          # stale prefix cache cleared
    assert out8 == out32                 # tiny model: quantization benign
    assert not sess.engine.layout.quantized
