"""Gradient-reduction strategies: all must equal the replica-mean (paper
§III-D.2 provides equivalence to sequential SGD; that starts here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (optional extra)")
from hypothesis import given, settings, strategies as st

from repro.core import allreduce as ar
from repro.core import broadcast as bc

P = jax.sharding.PartitionSpec


def _run_manual(fn, mesh, tree, extra_out_specs=None):
    """Run fn(tree_local) inside a manual region over pod+data."""
    in_specs = jax.tree.map(lambda _: P(("pod", "data")), tree)
    out_specs = jax.tree.map(lambda _: P(("pod", "data")), tree)
    sm = jax.shard_map(fn, mesh=mesh, in_specs=(in_specs,),
                       out_specs=out_specs, check_vma=False,
                       axis_names={"pod", "data"})
    return jax.jit(sm)(tree)


def _tree(rng, n_ranks):
    return {
        "a": jnp.asarray(rng.normal(size=(n_ranks, 6, 8)), jnp.float32),
        "b": {"w": jnp.asarray(rng.normal(size=(n_ranks, 17)), jnp.float32),
              "v": jnp.asarray(rng.normal(size=(n_ranks, 3, 3, 2)), jnp.float32)},
    }


@pytest.mark.parametrize("strategy", ["fused", "layerwise", "bucketed",
                                      "hierarchical"])
def test_strategy_equals_mean(mesh222, rng, strategy):
    tree = _tree(rng, 4)     # pod*data = 4 ranks; leading dim = rank

    def fn(local):
        local = jax.tree.map(lambda x: x[0], local)
        red, _ = ar.reduce_gradients(local, strategy, ("pod", "data"),
                                     bucket_bytes=128)
        return jax.tree.map(lambda x: x[None], red)

    out = _run_manual(fn, mesh222, tree)
    for k in ("a",):
        expect = np.mean(np.asarray(tree[k]), axis=0)
        got = np.asarray(out[k])
        for r in range(4):
            np.testing.assert_allclose(got[r], expect, atol=1e-6)


def test_compressed_error_feedback_converges(mesh222, rng):
    """With error feedback, repeated reduction of a CONSTANT gradient must
    average to the true mean over steps (residual cancels)."""
    tree = {"w": jnp.asarray(rng.normal(size=(4, 33)), jnp.float32)}

    def fn(local):
        g = jax.tree.map(lambda x: x[0], local)
        err = jax.tree.map(lambda x: jnp.zeros_like(x), g)
        acc = jax.tree.map(lambda x: jnp.zeros_like(x), g)
        for _ in range(8):
            red, err = ar.reduce_gradients(g, "compressed", ("pod", "data"),
                                           err=err)
            acc = jax.tree.map(jnp.add, acc, red)
        return jax.tree.map(lambda x: (x / 8)[None], acc)

    out = _run_manual(fn, mesh222, tree)
    expect = np.mean(np.asarray(tree["w"]), axis=0)
    np.testing.assert_allclose(np.asarray(out["w"])[0], expect, atol=5e-3)


def test_broadcast_makes_replicas_identical(mesh222, rng):
    tree = {"w": jnp.asarray(rng.normal(size=(4, 9)), jnp.float32)}

    def fn(local):
        g = jax.tree.map(lambda x: x[0], local)
        out = bc.broadcast_from_rank0(g, ("pod", "data"))
        return jax.tree.map(lambda x: x[None], out)

    out = np.asarray(_run_manual(fn, mesh222, tree)["w"])
    for r in range(4):
        np.testing.assert_allclose(out[r], np.asarray(tree["w"])[0],
                                   atol=1e-6)


def test_replicas_identical_detects_divergence(mesh222, rng):
    tree = {"w": jnp.asarray(rng.normal(size=(4, 9)), jnp.float32)}

    def fn(local):
        g = jax.tree.map(lambda x: x[0], local)
        d = bc.replicas_identical(g, ("pod", "data"))
        return jax.tree.map(lambda x: d[None], {"w": g["w"][:1]})

    d = float(np.asarray(_run_manual(fn, mesh222, tree)["w"]).max())
    assert d > 1e-3          # random tree: non-rank0 replicas differ


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(1, 40), min_size=1, max_size=6),
       st.integers(64, 4096))
def test_bucketing_partitions_all_leaves(sizes, bucket_bytes):
    """Property: bucketed reduction preserves every element exactly once
    (identity when world=1)."""
    rng = np.random.default_rng(0)
    tree = [jnp.asarray(rng.normal(size=(s,)), jnp.float32) for s in sizes]
    out = ar.bucketed_allreduce(tree, axes=(), bucket_bytes=bucket_bytes)
    for a, b in zip(tree, out):
        np.testing.assert_allclose(a, b, atol=0)
