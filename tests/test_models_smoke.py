"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward/train step + prefill + decode on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import registry

B, S = 2, 16


def _batch_for(cfg, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "encdec":
        return {"frames": jnp.asarray(
            rng.normal(size=(B, cfg.encdec.encoder_seq_len, cfg.d_model)),
            jnp.float32), "tokens": toks, "labels": labels}
    if cfg.family == "vlm":
        return {"tokens": toks,
                "patches": jnp.asarray(
                    rng.normal(size=(B, cfg.vlm.num_image_tokens, cfg.d_model)),
                    jnp.float32), "labels": labels}
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss_fn))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode(arch, rng):
    cfg = get_config(arch, smoke=True)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, rng)
    if cfg.family == "encdec":
        logits, state = jax.jit(bundle.prefill_fn)(params, batch["frames"],
                                                   batch["tokens"])
    elif cfg.family == "vlm":
        logits, state = jax.jit(bundle.prefill_fn)(params, batch["tokens"],
                                                   batch["patches"])
    else:
        logits, state = jax.jit(bundle.prefill_fn)(params, batch["tokens"])
    assert logits.shape == (B, cfg.vocab_size), (arch, logits.shape)
    assert jnp.all(jnp.isfinite(logits))
    tok = jnp.ones((B, 1), jnp.int32)
    logits2, state2 = jax.jit(bundle.decode_fn)(params, tok, state)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2)), f"{arch}: decode NaN"
    # decoding advances positions/state
    flat1 = jax.tree.leaves(state)
    flat2 = jax.tree.leaves(state2)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(flat1, flat2)), f"{arch}: state unchanged"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_constructs(arch):
    cfg = get_config(arch)
    cfg.validate()
    bundle = registry.build(cfg)
    structs = bundle.param_structs()
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(structs))
    assert n > 1e7, f"{arch}: implausibly small param count {n}"


EXPECTED_PARAMS_B = {
    # total params (billions) — tolerant windows around the published sizes;
    # assigned configs differ slightly from HF checkpoints (e.g. deepseek
    # uses the 64-expert assignment line), hence the slack.
    "qwen2.5-14b": (12, 18),
    "mistral-nemo-12b": (10, 14),
    "minitron-8b": (7, 11),
    "stablelm-1.6b": (1.2, 2.2),
    "recurrentgemma-2b": (2.0, 3.5),
    "rwkv6-1.6b": (1.2, 2.4),
    "mixtral-8x22b": (120, 155),
    "whisper-tiny": (0.02, 0.06),
    "pixtral-12b": (10, 14),
    "deepseek-v2-lite-16b": (10, 20),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED_PARAMS_B))
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    n = registry.count_params(cfg) / 1e9
    lo, hi = EXPECTED_PARAMS_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params outside [{lo},{hi}]"


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x22b")
    total = registry.count_params(cfg)
    active = registry.count_params(cfg, active_only=True)
    assert active < 0.45 * total        # 2 of 8 experts active + attn/embed
