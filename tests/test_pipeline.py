"""Pipelined submit/retire engine (plan -> submit -> retire, depth 2).

Four layers of guarantees:
  * token identity — the two-deep pipeline (plan/submit cycle N+1 while
    cycle N's device work is in flight) emits exactly the synchronous
    depth-1 engine's greedy tokens across the layout matrix (contiguous
    k/v, MLA latent, windowed ring pages), cold and warm, under a 2x2
    data x model mesh, and through preemption mid-pipeline;
  * config seam — ``ServeConfig.pipeline_depth`` validates (1 or 2,
    rejects others naming the knob);
  * plan memoization — ``PagedKVCachePool._plan`` memoizes by prompt
    until the prefix index changes; ``clear_prefix_cache()`` invalidates
    the memo along with the index;
  * observability — under a FakeClock the traced timeline shows
    submit(N+1) beginning before retire(N) runs (the overlap the pipeline
    exists for), and the ``engine.inflight`` counter reaches 2 at depth 2
    but never exceeds 1 at depth 1.
"""
import numpy as np
import pytest

from repro.configs import MeshConfig, ServeConfig, get_config
from repro.obs import INFLIGHT_COUNTER
from repro.serving import ServingEngine

ARCHS = {
    "full": ("qwen2.5-14b", {}),
    "mla": ("deepseek-v2-lite-16b", {}),
    "ring": ("mixtral-8x22b", {}),
}


class FakeClock:
    """Deterministic monotone clock: every read advances by ``tick``."""

    def __init__(self, tick=1.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


def _cfg(kind):
    arch, overrides = ARCHS[kind]
    cfg = get_config(arch, smoke=True)
    return cfg.replace(**overrides) if overrides else cfg


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


def _engine(cfg, depth, params=None, mesh_cfg=None, **kw):
    base = dict(max_batch=2, max_seq_len=40, max_new_tokens=5,
                decode_steps=2, kv_layout="paged",
                page_size=8 if cfg.attn_kind == "mla" else 4,
                pipeline_depth=depth)
    base.update(kw)
    return ServingEngine(cfg, ServeConfig(**base), params=params,
                         mesh_cfg=mesh_cfg)


# ---------------------------------------------------------------------------
# Config seam
# ---------------------------------------------------------------------------

def test_pipeline_depth_validates():
    ServeConfig(pipeline_depth=1).validate()
    ServeConfig(pipeline_depth=2).validate()
    for bad in (0, 3, -1):
        with pytest.raises(ValueError, match="pipeline_depth"):
            ServeConfig(pipeline_depth=bad).validate()


# ---------------------------------------------------------------------------
# Token identity: async (depth 2) == sync (depth 1), cold and warm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_async_matches_sync_cold_and_warm(kind):
    cfg = _cfg(kind)
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5, 9])
    prompts.append(list(prompts[0]))          # identical: warm-in-batch
    e_sync = _engine(cfg, 1)
    out_sync = e_sync.generate(prompts, 5)
    e_async = _engine(cfg, 2, params=e_sync.params)
    out_async = e_async.generate(prompts, 5)
    assert out_async == out_sync
    # warm pass: every block cached now; the pipeline must not move tokens
    e_async.metrics.reset()
    e_async.results.clear()
    assert e_async.generate(prompts, 5) == out_sync
    assert e_async.metrics.prefix_hit_tokens > 0
    # the pipeline drains clean: no in-flight cycle, no held pages
    assert e_async._inflight is None and not e_async._pending
    assert e_async.pool.pages_held == 0


def test_async_matches_sync_slotted():
    cfg = _cfg("full")
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, cfg.vocab_size, [6, 11, 8])
    e_sync = _engine(cfg, 1, kv_layout="slotted")
    e_async = _engine(cfg, 2, params=e_sync.params, kv_layout="slotted")
    assert e_async.generate(prompts, 5) == e_sync.generate(prompts, 5)


def test_async_matches_sync_chunked_prefill():
    """Long prompts split across cycles: chunk completions join the same
    cycle's decode rows; the capped first span (ring rotation hazard)
    must keep the device token chain intact."""
    cfg = _cfg("full")
    rng = np.random.default_rng(9)
    prompts = _prompts(rng, cfg.vocab_size, [23, 17, 30])
    kw = dict(max_seq_len=64, prefill_chunk_tokens=8)
    e_sync = _engine(cfg, 1, **kw)
    e_async = _engine(cfg, 2, params=e_sync.params, **kw)
    assert e_async.generate(prompts, 6) == e_sync.generate(prompts, 6)


def test_async_matches_sync_preemption_mid_pipeline():
    """Page pressure evicts a running request while its tokens are still
    in flight: the victim's un-retired tokens must emit before it is
    re-admitted, so the resumed prompt (prompt + generated) is exact."""
    cfg = _cfg("full")
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, cfg.vocab_size, [14, 15])
    kw = dict(max_seq_len=32, max_new_tokens=12, num_pages=12)
    e_sync = _engine(cfg, 1, **kw)
    out_sync = e_sync.generate(prompts, 12)
    e_async = _engine(cfg, 2, params=e_sync.params, **kw)
    out_async = e_async.generate(prompts, 12)
    assert e_async.metrics.preemptions >= 1
    assert out_async == out_sync


@pytest.mark.parametrize("kind", ["full", "mla"])
def test_async_matches_sync_under_mesh(kind):
    cfg = _cfg(kind)
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, cfg.vocab_size, [7, 11, 6, 9])
    # conftest forces 8 host devices: 2-way data (slots) x 2-way model (TP)
    mesh_cfg = MeshConfig(shape=(2, 2), axis_names=("data", "model"))
    e_mesh = _engine(cfg, 2, mesh_cfg=mesh_cfg, max_batch=4)
    out_mesh = e_mesh.generate(prompts, 4)
    out_single = _engine(cfg, 1, params=e_mesh.params,
                         max_batch=4).generate(prompts, 4)
    assert out_mesh == out_single


# ---------------------------------------------------------------------------
# Plan memoization
# ---------------------------------------------------------------------------

def test_plan_memo_hits_and_clear_invalidates():
    cfg = _cfg("full")
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab_size, [12, 12])
    prompts[1] = list(prompts[0])             # steady-state repeat traffic
    eng = _engine(cfg, 2)
    eng.generate(prompts, 4)
    pool = eng.pool
    key = tuple(prompts[0])
    plan1 = pool._plan(key)
    assert plan1[2] > 0                       # cached tokens found
    # memo hit: identical object back, no re-walk of the chain index
    assert pool._plan(key) is plan1
    assert key in pool._plan_cache
    pool.clear_prefix_cache()
    assert not pool._plan_cache               # memo dropped with the index
    plan2 = pool._plan(key)
    assert plan2[2] == 0                      # nothing cached anymore
    # index changes (not just clears) also invalidate: re-serving rebuilds
    # the index and the memo tracks the new version
    eng.results.clear()
    eng.generate(prompts, 4)
    plan3 = pool._plan(key)
    assert plan3[2] > 0 and plan3 is not plan1


# ---------------------------------------------------------------------------
# Observability: the overlap is visible in the traced timeline
# ---------------------------------------------------------------------------

def _traced_run(depth):
    cfg = _cfg("full")
    eng = ServingEngine(cfg, ServeConfig(
        max_batch=2, max_seq_len=40, max_new_tokens=5, decode_steps=2,
        kv_layout="paged", page_size=4, pipeline_depth=depth, trace=True),
        clock=FakeClock())
    rng = np.random.default_rng(3)
    eng.generate(_prompts(rng, cfg.vocab_size, [7, 12, 5]), 5)
    return eng.tracer


def test_submit_next_begins_before_previous_retire():
    """Depth 2: cycle N's results are retired *after* cycle N+1 has been
    planned and submitted — in the trace, the ``step.submit`` span of a
    step whose ``step.retire`` drains a pending cycle begins before that
    retire does.  Under the FakeClock every span boundary is a distinct
    tick, so the ordering is exact, not racy."""
    tr = _traced_run(2)
    spans = [e for e in tr.events if e[0] == "X"]
    steps = [e for e in spans if e[1] == "step"]
    overlapped = 0
    for st in steps:
        t0, t1 = st[3], st[3] + st[4]
        inside = [e for e in spans if t0 <= e[3] and e[3] + e[4] <= t1
                  and e[1] in ("step.submit", "step.retire")]
        sub = next((e for e in inside if e[1] == "step.submit"), None)
        ret = next((e for e in inside if e[1] == "step.retire"), None)
        if sub is None or ret is None or not (ret[5] or {}).get("pending"):
            continue
        overlapped += 1
        assert sub[3] < ret[3], (sub, ret)            # submit(N+1) first
        assert sub[3] < ret[3] + ret[4]               # ... before retire(N) ends
    assert overlapped >= 2, "pipeline never had a cycle in flight"


def test_inflight_counter_depth():
    """The ``engine.inflight`` Perfetto counter peaks at 2 exactly when
    the pipeline is two deep; the synchronous escape hatch never has more
    than one cycle outstanding."""
    def peak(depth):
        tr = _traced_run(depth)
        vals = [e[4] for e in tr.events
                if e[0] == "C" and e[1] == INFLIGHT_COUNTER]
        return max(vals, default=0)
    assert peak(2) == 2
    assert peak(1) == 1
