"""Per-request sampling + speculative decoding (serving/sampling.py,
serving/spec.py, engine integration).

The product guarantee under test: a request's tokens are a pure function
of (prompt, SamplingParams) — the counter-based PRNG keys every draw by
(request seed, absolute token index), so batch composition, slot
assignment, preemption/resume, paged vs slotted layout, a 2x2 mesh, warm
vs cold prefix caches and the pipeline depth must all be invisible in the
output.  Speculative decoding rides the same guarantee: verification
deterministically replays the engine's own sampler at each drafted
position, so spec-on output is token-identical to spec-off, greedy and
sampled alike.
"""
import numpy as np
import pytest

from repro.configs import MeshConfig, ServeConfig, get_config
from repro.serving import GREEDY, SamplingParams, ServingEngine
from repro.serving.sampling import pack_params, sample_tokens
from repro.serving.spec import NGramDrafter

ARCHS = {
    "full": ("qwen2.5-14b", {}),
    "mla": ("deepseek-v2-lite-16b", {}),
    "ring": ("mixtral-8x22b", {}),
}

#: one non-trivial sampled config reused across the matrix
SAMPLED = SamplingParams(temperature=0.8, top_k=8, top_p=0.9, seed=13)


def _cfg(kind):
    arch, overrides = ARCHS[kind]
    cfg = get_config(arch, smoke=True)
    return cfg.replace(**overrides) if overrides else cfg


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


def _rep_prompt(vocab, n=12, a=7, b=3):
    """A repetitive prompt the n-gram drafter can always propose from."""
    return ([a % vocab, b % vocab] * n)[:n]


def _engine(cfg, depth=1, params=None, mesh_cfg=None, **kw):
    base = dict(max_batch=2, max_seq_len=40, max_new_tokens=5,
                decode_steps=2, kv_layout="paged",
                page_size=8 if cfg.attn_kind == "mla" else 4,
                pipeline_depth=depth)
    base.update(kw)
    return ServingEngine(cfg, ServeConfig(**base), params=params,
                         mesh_cfg=mesh_cfg)


# ---------------------------------------------------------------------------
# Config seams: SamplingParams + ServeConfig spec knobs validate loudly
# ---------------------------------------------------------------------------

def test_sampling_params_validate():
    p = SamplingParams(temperature=0.5, top_k=4, top_p=0.9, seed=7)
    assert not p.greedy and GREEDY.greedy
    assert SamplingParams(temperature=0.0).greedy
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    for bad_p in (0.0, 1.5, -0.2):
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=bad_p)
    for bad_s in (-1, 2 ** 31):
        with pytest.raises(ValueError, match="seed"):
            SamplingParams(seed=bad_s)


def test_serve_config_spec_knobs_validate():
    ServeConfig(spec_tokens=8, enable_spec=False).validate()
    with pytest.raises(ValueError, match="spec_tokens"):
        ServeConfig(spec_tokens=0).validate()
    with pytest.raises(ValueError, match="enable_spec"):
        ServeConfig(enable_spec="yes").validate()


def test_engine_rejects_non_sampling_params():
    eng = _engine(_cfg("full"))
    with pytest.raises(TypeError, match="sampling"):
        eng.submit([1, 2, 3], sampling={"temperature": 1.0})


# ---------------------------------------------------------------------------
# The sampler itself (pure device function)
# ---------------------------------------------------------------------------

def test_sample_tokens_greedy_and_determinism():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(4, 32)).astype(np.float32)
    idx = np.arange(4, dtype=np.int32) + 10
    packed = np.stack([pack_params(GREEDY)] * 4)
    out = np.asarray(sample_tokens(logits, packed, idx))
    assert (out == logits.argmax(-1)).all()        # temp 0 -> argmax
    # top_k=1 pins the support to the argmax whatever the temperature
    packed1 = np.stack([pack_params(SamplingParams(
        temperature=2.0, top_k=1, seed=5))] * 4)
    out1 = np.asarray(sample_tokens(logits, packed1, idx))
    assert (out1 == logits.argmax(-1)).all()
    # pure function of (logits, params, idx): row position is irrelevant
    packed_s = np.stack([pack_params(SAMPLED)] * 4)
    a = np.asarray(sample_tokens(logits, packed_s, idx))
    b = np.asarray(sample_tokens(logits[::-1], packed_s[::-1], idx[::-1]))
    assert (a == b[::-1]).all()
    # ... but the counter index matters (different position, fresh draw)
    low_t = np.stack([pack_params(SamplingParams(
        temperature=5.0, seed=3))] * 4)
    flat = np.zeros((4, 32), np.float32)           # uniform -> index decides
    x = np.asarray(sample_tokens(flat, low_t, idx))
    y = np.asarray(sample_tokens(flat, low_t, idx + 17))
    assert (x != y).any()


def test_ngram_drafter_proposes_continuations():
    d = NGramDrafter(ngram=2)
    hist = [1, 2, 3, 1, 2, 3, 1, 2]
    assert d.propose(hist, 3) == (3, 1, 2)         # replay after [1, 2]
    assert d.propose(hist + [9], 3) == ()          # unseen suffix [2, 9]
    assert d.propose(hist, 0) == ()


# ---------------------------------------------------------------------------
# Reproducibility matrix: same (prompt, params) -> same tokens, everywhere
# ---------------------------------------------------------------------------

def test_sampled_invariant_to_batch_composition():
    """The target request emits the same tokens served alone, batched with
    greedy neighbours, and batched with other sampled requests."""
    cfg = _cfg("full")
    rng = np.random.default_rng(3)
    target = _prompts(rng, cfg.vocab_size, [9])[0]
    others = _prompts(rng, cfg.vocab_size, [7, 12, 5])
    e = _engine(cfg, max_batch=2)
    alone = e.generate([target], 5, sampling=SAMPLED)[0]
    e2 = _engine(cfg, params=e.params, max_batch=2)
    mixed = e2.generate([others[0], target, others[1]], 5,
                        sampling=[None, SAMPLED, None])
    assert mixed[1] == alone
    e3 = _engine(cfg, params=e.params, max_batch=2)
    allsamp = e3.generate(
        [others[2], target], 5,
        sampling=[SamplingParams(temperature=1.3, seed=99), SAMPLED])
    assert allsamp[1] == alone


@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_sampled_pipeline_depth_invariant(kind):
    cfg = _cfg(kind)
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5])
    samp = [SAMPLED, None, SamplingParams(temperature=1.1, top_p=0.8,
                                          seed=21)]
    e1 = _engine(cfg, depth=1)
    out1 = e1.generate(prompts, 5, sampling=samp)
    e2 = _engine(cfg, depth=2, params=e1.params)
    assert e2.generate(prompts, 5, sampling=samp) == out1


def test_sampled_paged_matches_slotted():
    cfg = _cfg("full")
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, cfg.vocab_size, [6, 11])
    e_paged = _engine(cfg)
    out_paged = e_paged.generate(prompts, 5, sampling=SAMPLED)
    e_slot = _engine(cfg, params=e_paged.params, kv_layout="slotted")
    assert e_slot.generate(prompts, 5, sampling=SAMPLED) == out_paged


def test_sampled_under_mesh_matches_single_device():
    cfg = _cfg("full")
    rng = np.random.default_rng(6)
    prompts = _prompts(rng, cfg.vocab_size, [7, 11, 6, 9])
    mesh_cfg = MeshConfig(shape=(2, 2), axis_names=("data", "model"))
    e_mesh = _engine(cfg, depth=2, mesh_cfg=mesh_cfg, max_batch=4)
    out_mesh = e_mesh.generate(prompts, 4, sampling=SAMPLED)
    e_one = _engine(cfg, params=e_mesh.params, max_batch=4)
    assert e_one.generate(prompts, 4, sampling=SAMPLED) == out_mesh


def test_sampled_warm_vs_cold_prefix_cache():
    """A warm prefix cache changes how much prefill runs, never which
    tokens come out; the greedy next-token memo must not serve a sampled
    request's first token."""
    cfg = _cfg("full")
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab_size, [12, 12])
    prompts[1] = list(prompts[0])                  # identical prompts
    eng = _engine(cfg, depth=2, enable_prefix_cache=True)
    # greedy pass seeds the prefix cache AND the next-token memo
    greedy_out = eng.generate(prompts, 5)
    assert greedy_out[0] == greedy_out[1]
    eng.metrics.reset()
    eng.results.clear()
    cold = _engine(cfg, params=eng.params,
                   enable_prefix_cache=False).generate(
        [prompts[0]], 5, sampling=SAMPLED)[0]
    warm = eng.generate(prompts, 5, sampling=SAMPLED)
    assert warm[0] == warm[1] == cold
    assert eng.metrics.prefix_hit_tokens > 0       # pages were shared
    assert cold != greedy_out[0]                   # sampling actually sampled


def test_sampled_preemption_resume_exact():
    """Page pressure evicts a sampled request mid-run; on resume it lands
    in a different slot with a longer prompt — the counter-keyed PRNG
    must replay the identical continuation."""
    cfg = _cfg("full")
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, cfg.vocab_size, [14, 15])
    kw = dict(max_seq_len=32, max_new_tokens=12)
    e_calm = _engine(cfg, **kw)
    out_calm = e_calm.generate(prompts, 12, sampling=SAMPLED)
    e_tight = _engine(cfg, depth=2, params=e_calm.params, num_pages=12, **kw)
    out_tight = e_tight.generate(prompts, 12, sampling=SAMPLED)
    assert e_tight.metrics.preemptions >= 1
    assert out_tight == out_calm


def test_greedy_params_identical_to_default_path():
    """temperature=0 through the sampling machinery is byte-identical to
    the plain greedy engine — including in a mixed batch, where greedy
    rows ride the sampled scan."""
    cfg = _cfg("full")
    rng = np.random.default_rng(8)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12])
    e = _engine(cfg)
    plain = e.generate(prompts, 5)
    e2 = _engine(cfg, params=e.params)
    explicit = e2.generate(prompts, 5, sampling=SamplingParams())
    assert explicit == plain
    e3 = _engine(cfg, params=e.params, max_batch=2)
    mixed = e3.generate([prompts[0], prompts[1]], 5,
                        sampling=[None, SAMPLED])
    assert mixed[0] == plain[0]


# ---------------------------------------------------------------------------
# Speculative decoding: spec-on == spec-off, tokens and pool hygiene
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_spec_identity_greedy(kind):
    """Repetitive prompts make the drafter propose every cycle; accepted
    or rejected, the output must match the spec-off engine exactly."""
    cfg = _cfg(kind)
    rng = np.random.default_rng(2)
    prompts = [_rep_prompt(cfg.vocab_size, a=1, b=1),
               _prompts(rng, cfg.vocab_size, [9])[0],
               _rep_prompt(cfg.vocab_size, n=16, a=1, b=1)]
    kw = dict(max_seq_len=48, max_new_tokens=16, spec_tokens=4)
    e_off = _engine(cfg, enable_spec=False, **kw)
    out_off = e_off.generate(prompts, 16)
    e_on = _engine(cfg, params=e_off.params, enable_spec=True, **kw)
    out_on = e_on.generate(prompts, 16)
    assert e_on.metrics.drafted_tokens > 0, "spec never engaged"
    assert out_on == out_off
    # drained clean: no spec wait, no held pages, symmetric pending
    assert not e_on._spec_wait and not e_on._pending
    assert e_on.pool.pages_held == 0


@pytest.mark.parametrize("depth", [1, 2])
def test_spec_identity_sampled(depth):
    """Verification replays the sampled distribution too: spec-on output
    with temperature > 0 is identical to spec-off at both depths."""
    cfg = _cfg("full")
    rng = np.random.default_rng(14)
    prompts = [_rep_prompt(cfg.vocab_size),
               _prompts(rng, cfg.vocab_size, [8])[0]]
    # top_k=1 rides the sampled lowering (temp > 0 -> categorical) while
    # keeping the output cyclic enough for the bigram drafter to engage;
    # the second request samples freely and must match spec-off too
    samp = [SamplingParams(temperature=0.7, top_k=1, seed=42),
            SamplingParams(temperature=0.6, top_k=4, seed=42)]
    kw = dict(max_seq_len=48, max_new_tokens=14, spec_tokens=4)
    e_off = _engine(cfg, enable_spec=False, **kw)
    out_off = e_off.generate(prompts, 14, sampling=samp)
    e_on = _engine(cfg, depth=depth, params=e_off.params, enable_spec=True,
                   **kw)
    out_on = e_on.generate(prompts, 14, sampling=samp)
    assert e_on.metrics.drafted_tokens > 0
    assert out_on == out_off


def test_spec_acceptance_happens():
    """Greedy decode of a tiny model falls into an argmax cycle; once the
    generated history repeats, drafts are the model's own continuation
    and must be accepted (accept_rate > 0), shrinking decode dispatches
    without changing a single token."""
    cfg = _cfg("full")
    prompts = [_rep_prompt(cfg.vocab_size, n=8)]
    kw = dict(max_seq_len=64, max_new_tokens=32, spec_tokens=8)
    e_off = _engine(cfg, enable_spec=False, **kw)
    out_off = e_off.generate(prompts, 32)
    e_on = _engine(cfg, params=e_off.params, enable_spec=True, **kw)
    out_on = e_on.generate(prompts, 32)
    assert out_on == out_off
    m = e_on.metrics
    assert m.drafted_tokens > 0 and m.accepted_tokens > 0
    s = m.summary()
    assert 0.0 < s["accept_rate"] <= 1.0
    assert s["accepted_tokens"] == m.accepted_tokens


def test_spec_traced_phases_and_metrics(tmp_path):
    """Traced spec run: step.draft and verify.device appear, the section
    spans still tile the step (coverage >= 0.95), and the spec counters
    flow through summary() and prometheus_text."""
    from repro.obs import phase_coverage, prometheus_text
    cfg = _cfg("full")
    eng = _engine(cfg, depth=2, max_seq_len=64, max_new_tokens=24,
                  spec_tokens=8, trace=True)
    eng.generate([_rep_prompt(cfg.vocab_size, n=8)], 24)
    tr = eng.tracer
    assert tr.open_spans() == []
    assert phase_coverage(tr) >= 0.95
    names = {e[1] for e in tr.events}
    assert {"step.draft", "verify.device"} <= names
    s = eng.metrics.summary()
    assert s["drafted_tokens"] > 0
    assert s["verify_time_s"] > 0 and s["draft_time_s"] > 0
    txt = prometheus_text(s, tr)
    assert "repro_serving_accept_rate" in txt
    assert 'repro_serving_phase_seconds{phase="verify.device"}' in txt
