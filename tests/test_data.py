"""Data readers (paper §III-F): format parsing, shard disjointness/coverage,
prefetch pipeline."""
import gzip
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import BatchIterator, Prefetcher, device_put_global
from repro.data.readers import (cifar_reader, csv_reader, mnist_reader,
                                numpy_reader, synthetic_tokens)


def test_synthetic_shards_disjoint_and_cover():
    world = 4
    shards = [synthetic_tokens(100, 8, 20, rank=r, world=world, seed=7)
              for r in range(world)]
    total = sum(len(s.training_data) for s in shards)
    assert total == 20
    full = synthetic_tokens(100, 8, 20, rank=0, world=1, seed=7)
    seen = np.concatenate([s.training_data for s in shards])
    assert {tuple(x) for x in seen.tolist()} == \
        {tuple(x) for x in full.training_data.tolist()}


def test_numpy_reader(tmp_path, rng):
    data = rng.normal(size=(10, 3)).astype(np.float32)
    labels = rng.integers(0, 5, 10).astype(np.int32)
    np.save(tmp_path / "d.npy", data)
    np.save(tmp_path / "l.npy", labels)
    ds = numpy_reader(str(tmp_path / "d.npy"), str(tmp_path / "l.npy"),
                      rank=1, world=2)
    np.testing.assert_array_equal(ds.training_data, data[1::2])
    np.testing.assert_array_equal(ds.training_labels, labels[1::2])


def test_csv_reader(tmp_path):
    rows = "\n".join(f"{i}.0,{i+1}.0,{i % 3}" for i in range(9))
    (tmp_path / "t.csv").write_text(rows + "\n")
    ds = csv_reader(str(tmp_path / "t.csv"), rank=0, world=3)
    assert ds.training_data.shape == (3, 2)
    np.testing.assert_array_equal(ds.training_labels, [0, 0, 0])


def test_mnist_reader(tmp_path, rng):
    imgs = rng.integers(0, 256, (6, 28, 28), dtype=np.uint8)
    labels = rng.integers(0, 10, 6, dtype=np.uint8)
    with gzip.open(tmp_path / "im.gz", "wb") as f:
        f.write(struct.pack(">IIII", 2051, 6, 28, 28))
        f.write(imgs.tobytes())
    with gzip.open(tmp_path / "lb.gz", "wb") as f:
        f.write(struct.pack(">II", 2049, 6))
        f.write(labels.tobytes())
    ds = mnist_reader(str(tmp_path / "im.gz"), str(tmp_path / "lb.gz"),
                      rank=0, world=2)
    assert ds.training_data.shape == (3, 28, 28, 1)
    assert ds.training_data.max() <= 1.0
    np.testing.assert_array_equal(ds.training_labels, labels[0::2])


def test_cifar_reader(tmp_path, rng):
    n = 4
    raw = np.zeros((n, 3073), np.uint8)
    raw[:, 0] = np.arange(n)
    raw[:, 1:] = rng.integers(0, 256, (n, 3072))
    raw.tofile(tmp_path / "c.bin")
    ds = cifar_reader(str(tmp_path / "c.bin"))
    assert ds.training_data.shape == (4, 32, 32, 3)
    np.testing.assert_array_equal(ds.training_labels, np.arange(n))


def test_batch_iterator_epochs():
    ds = synthetic_tokens(50, 4, 10)
    it = iter(BatchIterator(ds, batch=4, shuffle=True))
    seen = [next(it) for _ in range(5)]        # crosses an epoch boundary
    assert all(b["tokens"].shape == (4, 4) for b in seen)


def test_prefetcher_drains_fully():
    src = ({"x": np.full((2,), i)} for i in range(7))
    out = list(Prefetcher(src, depth=3))
    assert len(out) == 7
    assert int(out[-1]["x"][0]) == 6


def test_device_put_global_sharding(mesh42):
    batch = {"tokens": np.arange(32).reshape(8, 4).astype(np.int32)}
    g = device_put_global(batch, mesh42, ("data",))
    assert g["tokens"].shape == (8, 4)
    np.testing.assert_array_equal(np.asarray(g["tokens"]), batch["tokens"])
    assert len(g["tokens"].sharding.device_set) == 8
