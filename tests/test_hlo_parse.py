"""Roofline HLO analyzer: scan trip-count correction + collective capture
(the calibration that justifies not trusting cost_analysis — DESIGN.md §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_parse import analyze_module

P = jax.sharding.PartitionSpec


def test_scan_flops_exact():
    D, L, B = 128, 8, 4
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)

    def scanned(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        return jax.lax.scan(body, x, w)[0]

    compiled = jax.jit(scanned).lower(w, x).compile()
    stats = analyze_module(compiled.as_text())
    assert stats.dot_flops == pytest.approx(2 * B * D * D * L, rel=1e-6)
    assert L in stats.while_trip_counts
    # XLA's own analysis undercounts by exactly the trip count
    from repro.core.compat import cost_analysis
    ca = cost_analysis(compiled)
    assert ca["flops"] == pytest.approx(stats.dot_flops / L, rel=0.2)


def test_nested_scan_multiplicity():
    D, L1, L2 = 64, 3, 5
    w = jax.ShapeDtypeStruct((L1, L2, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((2, D), jnp.float32)

    def fn(w, x):
        def outer(x, wo):
            def inner(x, wi):
                return x @ wi, None
            return jax.lax.scan(inner, x, wo)[0], None
        return jax.lax.scan(outer, x, w)[0]

    stats = analyze_module(jax.jit(fn).lower(w, x).compile().as_text())
    assert stats.dot_flops == pytest.approx(2 * 2 * D * D * L1 * L2, rel=1e-6)


def test_collectives_captured_with_groups(mesh42):
    def step(x):
        return jax.lax.pmean(x, "data")

    sm = jax.shard_map(step, mesh=mesh42, in_specs=P("data"), out_specs=P(),
                       check_vma=False)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    stats = analyze_module(jax.jit(sm).lower(x).compile().as_text())
    kinds = {c.kind for c in stats.collectives}
    assert "all-reduce" in kinds
    ar = [c for c in stats.collectives if c.kind == "all-reduce"][0]
    assert ar.group_size == 4
    # per-device buffer: [2,128] f32 = 1024B; ring wire = 2*N*(p-1)/p
    assert ar.result_bytes == 2 * 128 * 4
    assert ar.wire_bytes == pytest.approx(2 * 1024 * 3 / 4)


def test_collective_inside_scan_multiplied(mesh42):
    L = 6

    def step(w, x):
        def body(x, wl):
            y = x @ wl
            return jax.lax.pmean(y, "data"), None
        return jax.lax.scan(body, x, w)[0]

    sm = jax.shard_map(step, mesh=mesh42,
                       in_specs=(P(), P("data", None)), out_specs=P("data", None),
                       check_vma=False)
    w = jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    stats = analyze_module(jax.jit(sm).lower(w, x).compile().as_text())
    ars = [c for c in stats.collectives if c.kind == "all-reduce"]
    total_count = sum(c.multiplicity for c in ars)
    assert total_count == pytest.approx(L)


def test_conv_flops_counted():
    x = jax.ShapeDtypeStruct((2, 16, 16, 3), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 3, 8), jnp.float32)

    def fn(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    stats = analyze_module(jax.jit(fn).lower(x, w).compile().as_text())
    expect = 2 * (2 * 16 * 16 * 8) * (3 * 3 * 3)
    assert stats.conv_flops == pytest.approx(expect, rel=0.35)
