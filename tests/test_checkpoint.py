"""Checkpoint/restore, async save, elastic re-meshing, fault recovery,
straggler detection — the fault-tolerance substrate (paper §II-B/III-B)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
from repro.checkpoint.elastic import restore_elastic, shrink_mesh_config
from repro.checkpoint.failures import (FaultInjector, SimulatedFault,
                                       StragglerMonitor, run_with_recovery)
from repro.configs import get_config
from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.transparent import TransparentTrainer
from repro.models import registry

SHAPE = ShapeConfig(name="t", kind="train", seq_len=16, global_batch=8)


def _trainer(mesh_shape=(2, 2), axes=("data", "model"), **kw):
    cfg = get_config("stablelm-1.6b", smoke=True)
    bundle = registry.build(cfg)
    run = RunConfig(model=cfg, shape=SHAPE,
                    mesh=MeshConfig(shape=mesh_shape, axis_names=axes, **kw),
                    optimizer=OptimizerConfig(name="adam", lr=1e-2))
    return TransparentTrainer(run, bundle.loss_fn, bundle.specs), cfg


def _batch(cfg, rng):
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                  jnp.int32)}


def test_save_restore_roundtrip(tmp_path, rng):
    tr, cfg = _trainer()
    state = tr.init(0)
    batch = _batch(cfg, rng)
    state, _ = tr.step(state, batch)
    save_checkpoint(tmp_path, state, 1)
    assert latest_step(tmp_path) == 1
    like = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        tr.state_structs())
    restored, step = restore_checkpoint(tmp_path, like,
                                        shardings=tr.state_shardings())
    assert step == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues identically from the restore
    s1, m1 = tr.step(state, batch)
    s2, m2 = tr.step(restored, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-6)


def test_async_save(tmp_path, rng):
    tr, cfg = _trainer()
    state = tr.init(0)
    h = save_checkpoint(tmp_path, state, 5, blocking=False)
    assert h.wait(30), "async save did not complete"
    assert latest_step(tmp_path) == 5


def test_checkpoint_pruning(tmp_path, rng):
    tr, cfg = _trainer()
    state = tr.init(0)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, state, s, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_000000004", "step_000000005"]


def test_elastic_restore_smaller_mesh(tmp_path, rng):
    """Paper §III-B: DP replication makes losing a replica recoverable.
    Train on data=4, checkpoint, resume on data=2."""
    big, cfg = _trainer(mesh_shape=(4, 2), axes=("data", "model"))
    state = big.init(0)
    batch = _batch(cfg, rng)
    state, m_big = big.step(state, batch)
    save_checkpoint(tmp_path, state, 1)

    small_cfg = shrink_mesh_config(
        MeshConfig(shape=(4, 2), axis_names=("data", "model")), 2)
    assert small_cfg.shape == (2, 2)
    small, _ = _trainer(mesh_shape=(2, 2))
    restored, step = restore_elastic(tmp_path, small)
    s2, m_small = small.step(restored, batch)
    # same global batch, same params -> same loss on the smaller mesh
    state3, m_big2 = big.step(state, batch)
    assert float(m_small["loss"]) == pytest.approx(float(m_big2["loss"]),
                                                   abs=1e-4)


def test_run_with_recovery_injected_fault(tmp_path):
    """ULFM-style continued execution: fault at step 7 -> restart from the
    step-5 checkpoint -> finish; loss history must cover all steps."""
    cfg = get_config("stablelm-1.6b", smoke=True)
    bundle = registry.build(cfg)
    rng = np.random.default_rng(0)
    batches = [_batch(cfg, rng) for _ in range(16)]

    def make_trainer(attempt):
        tr, _ = _trainer()
        return tr

    def data_iter_factory(start_step):
        return iter(batches[start_step:])

    state, hist = run_with_recovery(
        make_trainer=make_trainer, data_iter_factory=data_iter_factory,
        ckpt_dir=tmp_path, total_steps=12, ckpt_every=5,
        injector=FaultInjector(fail_at_steps=(7,)))
    assert hist["restarts"] == 1
    assert hist["resume_steps"] == [5]
    steps_seen = [s for s, _ in hist["losses"]]
    assert steps_seen[-1] == 12


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(k=5.0, warmup=2)
    for _ in range(10):
        mon.record(0.100 + np.random.default_rng(0).normal() * 1e-4)
    assert mon.record(0.5) is True
    assert mon.summary()["stragglers"]


def test_straggler_monitor_quiet_on_uniform():
    mon = StragglerMonitor(k=5.0, warmup=2)
    flags = [mon.record(0.1) for _ in range(20)]
    assert not any(flags)
