"""Pluggable KV-layout matrix (paged MLA + windowed attention, PR 5).

Four layers of guarantees:
  * layout seam — ``layout_for`` / registry capabilities are driven by the
    layout, never by ``attn_kind`` string probes; windowed page-size
    validation rejects pages that cannot tile the window, naming both
    knobs;
  * token identity — the paged pool (latent pages for MLA, ring-wrapped
    window pages for swa/local) emits exactly the slotted pool's greedy
    tokens: cold, warm (prefix hits incl. the COW'd fully-cached prompt),
    under a 2x2 data x model mesh, and through preemption;
  * ring invariants — a windowed slot never holds more than
    ``window // page_size`` pages; rotation parks indexed pages in the
    prefix LRU (refcount 0) instead of corrupting them, reuses private
    pages in place, and never aliases a private page into two tables;
  * Session hygiene — switching ``kv_layout`` on a live Session retires
    the incompatible engine and clears its prefix cache.
"""
import jax
import numpy as np
import pytest

from repro.configs import MeshConfig, ServeConfig, get_config
from repro.models import registry
from repro.serving import PagedKVCachePool, ServingEngine, layout_for
from repro.serving.layouts import KVLayout

ARCHS = {
    "mla": ("deepseek-v2-lite-16b", {}),
    "swa": ("mixtral-8x22b", {}),
    # no lm-family arch ships attn_kind="local"; the layout seam must not
    # care (local == swa masking with a different name)
    "local": ("mixtral-8x22b", {"attn_kind": "local"}),
    # contiguous per-head k/v pages (full attention) — the third page
    # geometry the Pallas kernel family must cover
    "full": ("qwen2.5-14b", {}),
}


def _cfg(kind):
    arch, overrides = ARCHS[kind]
    cfg = get_config(arch, smoke=True)
    return cfg.replace(**overrides) if overrides else cfg


def _prompts(rng, vocab, lengths):
    return [list(rng.integers(0, vocab, (l,))) for l in lengths]


def _engine(cfg, layout, params=None, mesh_cfg=None, **kw):
    base = dict(max_batch=2, max_seq_len=40, max_new_tokens=5,
                decode_steps=2, kv_layout=layout,
                page_size=8 if cfg.attn_kind == "mla" else 4)
    base.update(kw)
    return ServingEngine(cfg, ServeConfig(**base), params=params,
                         mesh_cfg=mesh_cfg)


# ---------------------------------------------------------------------------
# Layout seam / capability matrix
# ---------------------------------------------------------------------------

def test_layout_for_capability_matrix():
    assert layout_for(_cfg("mla")) == KVLayout("latent", ("ckv", "krope"))
    assert layout_for(_cfg("swa")).window == _cfg("swa").window
    assert layout_for(_cfg("local")).ring
    for kind in ARCHS:
        caps = registry.build(_cfg(kind)).capabilities()
        assert {"paged_serve", "prefix_serve"} <= caps, (kind, caps)
    # recurrent families have no layout and no paged contracts
    for arch in ("rwkv6-1.6b", "recurrentgemma-2b"):
        bundle = registry.build(get_config(arch, smoke=True))
        assert bundle.kv_layout is None
        assert "paged_serve" not in bundle.capabilities()


def test_window_page_size_validation_names_both_knobs():
    cfg = _cfg("swa")                                   # window = 8
    with pytest.raises(ValueError) as e:
        _engine(cfg, "paged", page_size=16, max_seq_len=32)
    assert "page_size" in str(e.value) and "window" in str(e.value)
    with pytest.raises(ValueError, match="window"):
        ServeConfig(page_size=4).check_window(6)        # 4 does not tile 6
    # slotted never pages: the same knobs are inert there
    _engine(cfg, "slotted", page_size=16, max_seq_len=32)


# ---------------------------------------------------------------------------
# Token identity: paged (latent / ring) == slotted, cold and warm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["gather", "kernel"])
@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_paged_matches_slotted_cold_and_warm(kind, use_pallas):
    """Greedy token identity paged == slotted, cold and warm — with the
    jnp gather path AND the Pallas kernels (interpret mode on CPU)
    driving every paged dispatch (decode, prefill chunks, verify)."""
    cfg = _cfg(kind)
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, cfg.vocab_size, [7, 12, 5, 9])
    prompts.append(list(prompts[0]))          # identical: warm-in-batch
    ep = _engine(cfg, "paged", use_pallas=use_pallas)
    assert ep.paged and ep.layout is not None
    assert ep.paged_kernel == use_pallas
    out_p = ep.generate(prompts, 5)
    es = _engine(cfg, "slotted", params=ep.params)
    assert not es.paged
    out_s = es.generate(prompts, 5)
    assert out_p == out_s
    # warm pass: every block is cached now; tokens must not move
    ep.metrics.reset()
    ep.results.clear()
    assert ep.generate(prompts, 5) == out_s
    assert ep.metrics.prefix_hit_tokens > 0
    # drain invariants: nothing referenced, counters balanced
    assert ep.pool.pages_held == 0
    assert int((ep.pool.refcount > 0).sum()) == 0
    assert ep.pool.pages_allocated == ep.pool.pages_freed
    # the latent layout's lazy pages undercut the slotted wall; the ring
    # layout matches the slotted ring's window bound from above (never
    # exceeds it)
    sp = ep.metrics.summary()
    assert 0 < sp["kv_bytes_peak"] <= sp["kv_bytes_slotted"]


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["gather", "kernel"])
@pytest.mark.parametrize("kind", ["mla", "swa", "full"])
def test_paged_matches_slotted_under_mesh(kind, use_pallas):
    cfg = _cfg(kind)
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, cfg.vocab_size, [7, 11, 6, 9])
    # conftest forces 8 host devices: 2-way data (slots) x 2-way model (TP)
    mesh_cfg = MeshConfig(shape=(2, 2), axis_names=("data", "model"))
    em = _engine(cfg, "paged", mesh_cfg=mesh_cfg, max_batch=4,
                 use_pallas=use_pallas)
    out_mesh = em.generate(prompts, 4)
    out_single = _engine(cfg, "slotted", params=em.params,
                         max_batch=4).generate(prompts, 4)
    assert out_mesh == out_single
    assert em.metrics.summary()["completed"] == len(prompts)


@pytest.mark.parametrize("kind", ["mla", "swa"])
def test_paged_preemption_identity(kind):
    """Oversubscribed pages force preemption; resumed requests re-prefill
    (typically from their own cached prefix) and emit identical tokens."""
    cfg = _cfg(kind)
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, cfg.vocab_size, [14, 15])
    ps = 8 if kind == "mla" else 4
    # enough for one slot's worst case (+1), short of two slots' worst
    width = min(-(-32 // ps), (cfg.window // ps) if kind == "swa" else 99)
    pool = width + max(width // 2, 1) + 1
    ep = _engine(cfg, "paged", max_seq_len=32, max_new_tokens=12,
                 num_pages=max(pool, -(-32 // ps) + 1),
                 prefill_chunk_tokens=6)
    out_p = ep.generate(prompts, 12)
    es = _engine(cfg, "slotted", params=ep.params, max_seq_len=32,
                 max_new_tokens=12)
    assert out_p == es.generate(prompts, 12)
    if kind == "mla":                      # ring pools rarely starve: a
        assert ep.metrics.preemptions >= 1  # slot never outgrows its ring
    assert int((ep.pool.refcount > 0).sum()) == 0
    assert ep.pool.pages_allocated == ep.pool.pages_freed


def test_mla_cow_isolation_on_fully_cached_prompt():
    """A fully page-aligned cached MLA prompt re-admits without touching
    the shared latent pages, through either full-hit regime:

      * last-token replay + copy-on-write — when the blocks are indexed
        but the exact prompt's next token is unknown (here: committed by
        a longer prompt that extends it);
      * the zero-dispatch fast path — once the exact prompt has run, its
        greedy next token is memoized (``cache_next_token``) and the
        re-admission skips the replay AND the COW entirely.

    In both, the shared pages stay bit-identical while decode writes."""
    cfg = _cfg("mla")
    rng = np.random.default_rng(9)
    prompt = list(rng.integers(0, cfg.vocab_size, (16,)))   # 2 full pages
    ep = _engine(cfg, "paged", decode_steps=1, max_new_tokens=8)
    # A extends the prompt: its commit indexes the two full blocks, but the
    # next-token memo is keyed by A's *full* prompt — B must COW-replay
    ra = ep.submit(prompt + list(rng.integers(0, cfg.vocab_size, (3,))),
                   max_new_tokens=8)
    ep.run()
    from repro.serving.paged import block_hashes
    shared = [ep.pool._index[h][0] for h in block_hashes(prompt, 8)]
    assert shared and all(p is not None for p in shared)
    snap = {pid: (np.asarray(ep.pool.pages["ckv"][:, pid]),
                  np.asarray(ep.pool.pages["krope"][:, pid]))
            for pid in shared}
    assert ep.pool.cached_next_token(prompt) is None
    rb = ep.submit(prompt, max_new_tokens=8)
    out = ep.run()
    assert ep.pool.cow_copies >= 1         # replay regime: COW taken
    for pid, (c0, k0) in snap.items():
        np.testing.assert_array_equal(
            np.asarray(ep.pool.pages["ckv"][:, pid]), c0)
        np.testing.assert_array_equal(
            np.asarray(ep.pool.pages["krope"][:, pid]), k0)
    # B's completion memoized its next token: an exact repeat now takes
    # the fast path — no new COW, same tokens, shared pages still intact
    cows = ep.pool.cow_copies
    assert ep.pool.cached_next_token(prompt) is not None
    rc = ep.submit(prompt, max_new_tokens=8)
    out2 = ep.run()
    assert ep.pool.cow_copies == cows
    assert out2[rc] == out[rb]
    for pid, (c0, k0) in snap.items():
        np.testing.assert_array_equal(
            np.asarray(ep.pool.pages["ckv"][:, pid]), c0)
        np.testing.assert_array_equal(
            np.asarray(ep.pool.pages["krope"][:, pid]), k0)


# ---------------------------------------------------------------------------
# Ring (window) eviction invariants — pool white-box
# ---------------------------------------------------------------------------

def test_window_ring_rotation_invariants():
    """A windowed slot's pages are bounded by the ring; rotation parks
    indexed pages (refcount 0) in the LRU, reuses private pages in place,
    and the live tail stays matchable for the next admission."""
    cfg = _cfg("swa")                                    # window = 8
    bundle = registry.build(cfg)
    layout = bundle.kv_layout
    ps = 4
    pool = PagedKVCachePool(2, ps, 32,
                            lambda: bundle.init_decode_state(1, ps),
                            layout=layout, enable_prefix_cache=True)
    assert pool.table_width == cfg.window // ps == 2
    prompt = list(range(100, 120))                       # 20 tokens, 5 blocks
    s0, cached = pool.alloc_prefix(0, prompt)
    assert cached == 0 and len(pool.held[s0]) == 2       # ring, not 5 pages
    held_high = 0
    for lo, hi in ((0, 7), (8, 15), (16, 19)):           # window-capped chunks
        assert pool.prepare_chunk(s0, lo, hi)
        pool.commit_prefix(s0, prompt[:hi + 1])
        held_high = max(held_high, len(pool.held[s0]))
    assert held_high <= pool.table_width                 # never exceeds ring
    # rotated-out committed blocks parked in the LRU with refcount 0
    assert pool.cached_pages >= 2
    assert all(pool.refcount[p] == 0 for p in pool._cached_lru)
    # decode write at pos=20 rotates another cell; no starvation
    assert pool.ensure_decode_capacity() == []
    assert len(pool.held[s0]) <= pool.table_width
    # a second identical admission matches the live tail: blocks wholly
    # out of its window need no page and still count as cached
    s1, cached1 = pool.alloc_prefix(1, prompt)
    assert s1 is not None and cached1 >= 12
    # no private-page aliasing across the two tables
    shared = set(pool.held[s0]) & set(pool.held[s1])
    for pid in shared:
        assert pool.refcount[pid] >= 2                   # genuinely shared
    for pid in set(pool.held[s1]) - shared:
        assert pool.refcount[pid] == 1
    pool.evict(s0)
    pool.evict(s1)
    assert pool.pages_held == 0
    assert int((pool.refcount > 0).sum()) == 0
    assert pool.pages_allocated == pool.pages_freed


def test_phantom_index_entries_are_bounded():
    """Reclaiming indexed pages leaves phantom chain entries; a steady
    stream of distinct prompts must not grow the index without bound."""
    cfg = get_config("qwen2.5-14b", smoke=True)
    bundle = registry.build(cfg)
    ps = 4
    pool = PagedKVCachePool(2, ps, 8, lambda: bundle.init_decode_state(1, ps),
                            num_pages=6, enable_prefix_cache=True)
    rng = np.random.default_rng(13)
    for _ in range(200):                      # 200 distinct 2-block prompts
        prompt = list(rng.integers(0, cfg.vocab_size, (8,)))
        slot, _ = pool.alloc_prefix(0, prompt)
        pool.commit_prefix(slot, prompt)
        pool.evict(slot)
    # live entries are capped by the pool size; phantoms by the prune sweep
    assert len(pool._index) <= 8 * pool.num_pages
    assert pool.cached_pages <= pool.num_pages


def test_window_ring_insert_rejected():
    """The contiguous insert path cannot represent a ring cache — the
    prefix path (alloc_prefix + paged prefill) is the only admission."""
    cfg = _cfg("swa")
    bundle = registry.build(cfg)
    pool = PagedKVCachePool(1, 4, 16, lambda: bundle.init_decode_state(1, 4),
                            layout=bundle.kv_layout)
    with pytest.raises(ValueError, match="ring"):
        pool.insert(0, {"k": None, "v": None}, n_tokens=8)


# ---------------------------------------------------------------------------
# Session hygiene on layout switches
# ---------------------------------------------------------------------------

def test_session_layout_switch_drops_stale_engine():
    from repro import api
    sess = api.load("deepseek-v2-lite-16b", smoke=True, num_layers=2)
    prompt = list(range(4, 20))
    out_paged = sess.generate(prompt, max_new=4, kv_layout="paged")
    eng_paged = sess.engine
    assert eng_paged.paged and eng_paged.pool._index   # prefix cache warm
    out_slotted = sess.generate(prompt, max_new=4, kv_layout="slotted")
    # the paged engine is gone from the cache and its prefix cache cleared
    assert eng_paged not in sess._engines.values()
    assert not eng_paged.pool._index
    assert not eng_paged.pool._cached_lru
    assert out_paged == out_slotted
    # switching back builds a fresh engine (no stale pool resurrection)
    sess.generate(prompt, max_new=4, kv_layout="paged")
    assert sess.engine is not eng_paged
