"""Optimizer substrate: each §I optimizer converges on a quadratic;
clipping and global-norm properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (optional extra)")
from hypothesis import given, settings, strategies as st

from repro.configs.base import OptimizerConfig
from repro.optim.optimizers import (clip_by_global_norm, global_norm,
                                    make_optimizer)


@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.05),
                                     ("adagrad", 0.5), ("adam", 0.1),
                                     ("adamw", 0.1)])
def test_optimizer_converges_quadratic(name, lr):
    opt = make_optimizer(OptimizerConfig(name=name, lr=lr, weight_decay=1e-4))
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2, name


def test_adam_bias_correction_first_step():
    """After one step from zero moments, Adam moves by ~lr in sign(g)."""
    opt = make_optimizer(OptimizerConfig(name="adam", lr=1e-3))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1.0, -1.0, 2.0, -0.5])}
    params, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.abs(np.asarray(params["w"])), 1e-3,
                               rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 10.0), st.integers(1, 5))
def test_clip_bounds_global_norm(max_norm, n_leaves):
    rng = np.random.default_rng(42)
    tree = [jnp.asarray(rng.normal(size=(7,)) * 100, jnp.float32)
            for _ in range(n_leaves)]
    clipped, gn = clip_by_global_norm(tree, max_norm)
    new_norm = float(global_norm(clipped))
    assert new_norm <= max_norm * (1 + 1e-4)
    # direction preserved
    ratio = [np.asarray(c) / np.asarray(t) for c, t in zip(clipped, tree)]
    flat = np.concatenate([r.ravel() for r in ratio])
    np.testing.assert_allclose(flat, flat[0], rtol=1e-4)


def test_clip_noop_below_threshold():
    tree = {"w": jnp.asarray([3e-4, 4e-4])}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["w"]),
                               np.asarray(tree["w"]), rtol=1e-6)
