"""Test environment: 8 placeholder CPU devices for distribution tests.

Must run before any jax import.  The production dry-run (512 devices) sets
its own flag in its own process (launch/dryrun.py); benchmarks run with the
default single device.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (initialize after the flag)
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh222():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def mesh42():
    return jax.make_mesh((4, 2), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
