"""Test environment: 8 placeholder CPU devices for distribution tests.

Must run before any jax import.  The production dry-run (512 devices) sets
its own flag in its own process (launch/dryrun.py); benchmarks run with the
default single device.

Also makes the suite runnable without PYTHONPATH=src (falls back to the
src/ layout when ``repro`` isn't installed, e.g. before ``pip install -e .``)
and aliases ``jax.shard_map`` to the version-tolerant wrapper on jax
versions that predate it (tests exercise the new-style signature).
"""
import os
import sys
from pathlib import Path

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

try:
    import repro  # noqa: F401  (installed via pip install -e . ?)
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402  (initialize after the flag)
import numpy as np  # noqa: E402
import pytest  # noqa: E402

if not hasattr(jax, "shard_map"):
    from repro.core.compat import shard_map as _compat_shard_map
    jax.shard_map = _compat_shard_map


@pytest.fixture(scope="session")
def mesh222():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def mesh42():
    return jax.make_mesh((4, 2), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
