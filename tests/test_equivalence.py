"""Fig. 7 reproduction: synchronous distributed training is numerically
equivalent to the sequential run — the paper's central validation (§III-E:
"maintain numerical equivalence with the sequential algorithm").

Sequential = 1 replica, full batch.  Distributed = 4 DP replicas over the
same global batch.  Losses must match step for step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.transparent import TransparentTrainer
from repro.models import registry
from repro.models.cnn import cnn_loss, tinycnn_forward, tinycnn_specs

SHAPE = ShapeConfig(name="t", kind="train", seq_len=16, global_batch=8)
STEPS = 6


def _curve(trainer, batches):
    state = trainer.init(0)
    out = []
    for b in batches:
        state, m = trainer.step(state, b)
        out.append(float(m["loss"]))
    return out


def _lm_batches(cfg, rng, n):
    return [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                   jnp.int32)} for _ in range(n)]


@pytest.mark.parametrize("optimizer", ["sgd", "momentum", "adam", "adagrad"])
def test_lm_equivalence_seq_vs_dp4(optimizer):
    """Paper Fig. 7, LM flavour, for each §I gradient-descent variant."""
    cfg = get_config("stablelm-1.6b", smoke=True)
    bundle = registry.build(cfg)
    opt = OptimizerConfig(name=optimizer, lr=1e-2)
    rng = np.random.default_rng(1)
    batches = _lm_batches(cfg, rng, STEPS)

    seq_mesh = MeshConfig(shape=(1, 1), axis_names=("data", "model"))
    seq = TransparentTrainer(
        RunConfig(model=cfg, shape=SHAPE, mesh=seq_mesh, optimizer=opt),
        bundle.loss_fn, bundle.specs)
    seq_losses = _curve(seq, batches)

    dp_mesh = MeshConfig(shape=(4, 2), axis_names=("data", "model"),
                         allreduce="layerwise")
    dp = TransparentTrainer(
        RunConfig(model=cfg, shape=SHAPE, mesh=dp_mesh, optimizer=opt),
        bundle.loss_fn, bundle.specs)
    dp_losses = _curve(dp, batches)

    np.testing.assert_allclose(dp_losses, seq_losses, atol=5e-4,
                               err_msg="distributed != sequential (Fig. 7)")


def test_cnn_equivalence_seq_vs_dp4():
    """Paper Fig. 7 as published: CNN image classification."""
    from repro.models.common import init_params, param_shape_structs

    specs = tinycnn_specs(num_classes=10)
    loss_fn = lambda p, b: cnn_loss(tinycnn_forward, p, b, 10)
    rng = np.random.default_rng(2)
    batches = [{"images": jnp.asarray(rng.normal(size=(8, 16, 16, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(rng.integers(0, 10, (8,)), jnp.int32)}
               for _ in range(STEPS)]
    opt = OptimizerConfig(name="momentum", lr=1e-2)
    cfg = get_config("tinycnn")

    seq = TransparentTrainer(
        RunConfig(model=cfg, shape=SHAPE,
                  mesh=MeshConfig(shape=(1, 1), axis_names=("data", "model")),
                  optimizer=opt),
        loss_fn, specs)
    dp = TransparentTrainer(
        RunConfig(model=cfg, shape=SHAPE,
                  mesh=MeshConfig(shape=(4, 1), axis_names=("data", "model"),
                                  allreduce="layerwise"),
                  optimizer=opt),
        loss_fn, specs)
    seq_losses = _curve(seq, batches)
    dp_losses = _curve(dp, batches)
    np.testing.assert_allclose(dp_losses, seq_losses, atol=5e-4)
