"""MoE dispatch: capacity semantics, top-k combine correctness, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import moe as M
from repro.models.common import init_params


def _cfg(num_experts=4, top_k=2, cf=8.0):
    base = get_config("mixtral-8x22b", smoke=True)
    return base.replace(moe=MoEConfig(num_experts=num_experts, top_k=top_k,
                                      d_ff_expert=32, capacity_factor=cf))


def _dense_ref(cfg, p, x):
    """Every token through its top-k experts with no capacity limit."""
    m = cfg.moe
    B, S, D = x.shape
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x)
    for e in range(m.num_experts):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        wgt = jnp.sum(jnp.where(sel == e, gates, 0.0), axis=-1)
        out = out + wgt[..., None] * ye
    return out


def test_moe_matches_dense_when_capacity_ample(rng):
    cfg = _cfg(cf=8.0)      # capacity >> tokens: nothing dropped
    p = init_params(M.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)) * 0.5, jnp.float32)
    y, aux = M.moe_apply(cfg, p, x)
    ref = _dense_ref(cfg, p, x)
    np.testing.assert_allclose(y, ref, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(rng):
    """With capacity_factor << 1 the combine weights must drop tokens
    (outputs shrink toward zero) rather than corrupt them."""
    cfg_full = _cfg(cf=8.0)
    cfg_tight = _cfg(cf=0.25)
    p = init_params(M.moe_specs(cfg_full), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 16, cfg_full.d_model)), jnp.float32)
    y_full, _ = M.moe_apply(cfg_full, p, x)
    y_tight, _ = M.moe_apply(cfg_tight, p, x)
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))
    assert jnp.all(jnp.isfinite(y_tight))


def test_moe_aux_loss_prefers_balance():
    """Uniform routing must yield a (near-)minimal aux loss of ~1.0."""
    cfg = _cfg(num_experts=4, top_k=1)
    p = init_params(M.moe_specs(cfg), jax.random.PRNGKey(1))
    # zero router -> uniform probabilities -> balanced
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, cfg.d_model)),
                    jnp.float32)
    _, aux = M.moe_apply(cfg, p, x)
    assert 0.9 <= float(aux) <= 1.1


def test_moe_grads_flow_to_router_and_experts(rng):
    cfg = _cfg()
    p = init_params(M.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        y, aux = M.moe_apply(cfg, p, x)
        return jnp.mean(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_gate"]))) > 0


def test_shared_experts_path(rng):
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    p = init_params(M.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)
    y, aux = M.moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
