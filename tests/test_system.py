"""End-to-end system behaviour: the user-transparent contract (paper Fig. 3),
a small dry-run through the real launcher path, and the serve loop."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.transparent import TransparentTrainer
from repro.data.pipeline import device_put_global, make_input_pipeline
from repro.data.readers import synthetic_tokens
from repro.models import registry


def test_user_script_has_no_distribution_code(mesh42):
    """The paper's Fig. 3 contract, enforced: everything a 'user' writes
    below is sequential — data load, loss, optimizer choice.  The runtime
    (TransparentTrainer + data pipeline) adds sharding, broadcast and
    gradient reduction."""
    # --- user script (sequential) ---
    cfg = get_config("stablelm-1.6b", smoke=True)
    bundle = registry.build(cfg)
    loss_fn = bundle.loss_fn                       # plain (params, batch)
    ds = synthetic_tokens(cfg.vocab_size, 16, 64)  # plain arrays
    opt = OptimizerConfig(name="momentum", lr=1e-2)
    # --- runtime (the paper's contribution) ---
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("t", "train", 16, 8),
                    mesh=MeshConfig(shape=(4, 2),
                                    axis_names=("data", "model"),
                                    allreduce="layerwise"),
                    optimizer=opt)
    trainer = TransparentTrainer(run, loss_fn, bundle.specs, mesh=mesh42)
    it, pf = make_input_pipeline(ds, global_batch=8, mesh=mesh42,
                                 dp_axes=("data",))
    state = trainer.init(0)
    losses = []
    for _, batch in zip(range(10), it):
        state, m = trainer.step(state, batch)
        losses.append(float(m["loss"]))
    pf.close()
    # different random batches each step: compare trend, not adjacent steps
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    assert all(np.isfinite(l) for l in losses)


def test_greedy_decode_consistency(rng):
    """serve loop: greedy decode after prefill must equal teacher-forced
    forward logits (same tokens -> same distribution argmax)."""
    cfg = get_config("qwen2.5-14b", smoke=True)
    bundle = registry.build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(3))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)

    logits, state = jax.jit(bundle.prefill_fn)(params, prompt)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, state = jax.jit(bundle.decode_fn)(
            params, jnp.asarray([[toks[-1]]], jnp.int32), state)
        toks.append(int(jnp.argmax(logits[0])))

    # teacher-forced reference over the full sequence
    from repro.models.transformer import lm_forward, lm_head
    from repro.models.common import cast_tree
    full = jnp.concatenate(
        [prompt, jnp.asarray([toks[:-1]], jnp.int32)], axis=1)
    p32 = cast_tree(params, cfg.compute_dtype)
    x, _, _ = lm_forward(cfg, p32, full)
    ref_logits = lm_head(cfg, p32, x)
    for i in range(4):
        ref_tok = int(jnp.argmax(ref_logits[0, 7 + i]))
        assert toks[i] == ref_tok, f"greedy mismatch at step {i}"


def test_dryrun_cell_on_test_mesh():
    """The launcher's lowering path compiles on a small mesh in-process
    (the 512-device production run is exercised by launch/dryrun.py)."""
    cfg = get_config("stablelm-1.6b", smoke=True)
    bundle = registry.build(cfg)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 8),
                    mesh=MeshConfig(shape=(2, 2, 2),
                                    axis_names=("pod", "data", "model"),
                                    allreduce="layerwise"))
    trainer = TransparentTrainer.from_bundle(run, bundle, mesh=mesh)
    lowered = trainer.lower_step(bundle.train_input_specs(run.shape))
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    from repro.core.compat import cost_analysis
    assert cost_analysis(compiled).get("flops", 0) > 0
    from repro.roofline.hlo_parse import analyze_module
    stats = analyze_module(compiled.as_text())
    assert stats.dot_flops > 0
    assert any(c.kind == "all-reduce" for c in stats.collectives)
