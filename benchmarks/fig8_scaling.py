"""Paper Fig. 8: strong-scaling speedup, via the paper's own §IV-A model —
step time ~ C/p + comm(p) with comm growing ~log p (tree) / (p-1)/p (ring).

The paper measures 1..16 InfiniBand CPU nodes / 1..4 K40 GPUs; the TPU
analogue below predicts strong-scaling speedup for 1..16 v5e "nodes" (data-
parallel groups) from each arch's analytic compute cost and allreduce
volume, using the same batch-fixed strong-scaling setup (global batch 256).

Also reproduces the paper's qualitative finding: ratio (Fig. 6) orders the
speedup curves — AlexNet-like low-ratio models scale worst.
"""
from __future__ import annotations

import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.models import registry
from repro.roofline import hw

GLOBAL_BATCH = 256
SEQ = 512


def step_time(cfg, p: int) -> float:
    """Strong scaling: C/p compute + ring-allreduce gradients (fp32)."""
    n_active = registry.count_params(cfg, active_only=True)
    n_total = registry.count_params(cfg)
    tokens = GLOBAL_BATCH * SEQ
    compute = 6.0 * (n_active - cfg.vocab_size * cfg.d_model) * tokens \
        / hw.PEAK_FLOPS_BF16
    if p == 1:
        return compute
    wire = 2.0 * 4.0 * n_total * (p - 1) / p          # ring allreduce fp32
    return compute / p + wire / hw.ICI_BW_PER_LINK


def speedup_curve(cfg, ps=(1, 2, 4, 8, 16)):
    t1 = step_time(cfg, 1)
    return [t1 / step_time(cfg, p) for p in ps]


def run():
    results = []
    ps = (1, 2, 4, 8, 16)
    print("# Fig8: modeled strong-scaling speedup (global batch 256, v5e)")
    print(f"{'arch':26s} " + " ".join(f"p={p:<5d}" for p in ps))
    curves = {}
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        cur = speedup_curve(cfg, ps)
        curves[arch] = cur
        print(f"{arch:26s} " + " ".join(f"{s:6.2f}" for s in cur))
        results.append((f"fig8/{arch}/speedup@16", 0.0, cur[-1]))
    # the paper's ordering claim: higher compute/param ratio -> better scaling
    from benchmarks.fig456_ratios import rows as ratio_rows
    ratios = {a: r for a, _, _, r in ratio_rows() if a != "alexnet"}
    order_by_ratio = sorted(ratios, key=ratios.get)
    order_by_speedup = sorted(curves, key=lambda a: curves[a][-1])
    agree = np.mean([order_by_ratio.index(a) == order_by_speedup.index(a)
                     for a in ratios])
    print(f"# ratio-ordering vs speedup-ordering agreement: {agree:.0%}")
    results.append(("fig8/ordering_agreement", 0.0, float(agree)))
    return results


if __name__ == "__main__":
    run()
