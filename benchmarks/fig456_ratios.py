"""Paper Figs. 4-6: compute cost, parameter count and compute/parameter
ratio relative to AlexNet — the paper's scalability predictor ("models with
a higher ratio scale better"), applied to the assigned 10-arch pool.

Compute cost = forward FLOPs for one sample at seq 512 (LM) / one image
(CNN); parameters = total.  All analytic (registry accounting).
"""
from __future__ import annotations

import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.models import registry


def alexnet_costs():
    # conv stack flops for one 224x224x3 image (standard AlexNet accounting)
    convs = [
        (55 * 55 * 96, 11 * 11 * 3), (27 * 27 * 256, 5 * 5 * 96),
        (13 * 13 * 384, 3 * 3 * 256), (13 * 13 * 384, 3 * 3 * 384),
        (13 * 13 * 256, 3 * 3 * 384),
    ]
    fcs = [(256 * 6 * 6, 4096), (4096, 4096), (4096, 1000)]
    flops = sum(2 * o * k for o, k in convs) + sum(2 * i * o for i, o in fcs)
    params = sum(k * o // (o // o) for o, k in [])  # (conv params below)
    params = (11*11*3*96 + 5*5*96*256 + 3*3*256*384 + 3*3*384*384
              + 3*3*384*256 + 256*36*4096 + 4096*4096 + 4096*1000)
    return flops, params


def lm_forward_flops_per_sample(cfg, seq: int = 512) -> float:
    n = registry.count_params(cfg, active_only=True)
    n -= cfg.vocab_size * cfg.d_model
    return 2.0 * n * seq


def rows():
    a_flops, a_params = alexnet_costs()
    out = [("alexnet", 1.0, 1.0, 1.0)]
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        f = lm_forward_flops_per_sample(cfg) / a_flops
        p = registry.count_params(cfg) / a_params
        out.append((arch, f, p, f / p))
    return out


def run():
    results = []
    print("# Fig4-6: relative compute, params, ratio (AlexNet = 1.0)")
    print(f"{'arch':26s} {'compute':>10s} {'params':>10s} {'ratio':>8s}")
    for arch, f, p, r in rows():
        print(f"{arch:26s} {f:10.2f} {p:10.2f} {r:8.3f}")
        results.append((f"fig456/{arch}/ratio", 0.0, r))
    return results


if __name__ == "__main__":
    run()
