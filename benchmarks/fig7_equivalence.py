"""Paper Fig. 7: loss curves of distributed vs sequential training are
identical.  Runs in a subprocess with 8 placeholder devices (this module's
parent benchmark process keeps the default single device)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.configs.base import MeshConfig, OptimizerConfig, RunConfig, ShapeConfig
from repro.core.transparent import TransparentTrainer
from repro.models import registry

cfg = get_config("stablelm-1.6b", smoke=True)
bundle = registry.build(cfg)
rng = np.random.default_rng(7)
STEPS = 20
batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)}
           for _ in range(STEPS)]
shape = ShapeConfig("t", "train", 16, 8)
opt = OptimizerConfig(name="momentum", lr=5e-3)

def curve(mesh_shape, axes, **kw):
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig(shape=mesh_shape, axis_names=axes, **kw),
                    optimizer=opt)
    tr = TransparentTrainer(run, bundle.loss_fn, bundle.specs)
    st = tr.init(0)
    out = []
    for b in batches:
        st, m = tr.step(st, b)
        out.append(float(m["loss"]))
    return out

seq = curve((1, 1), ("data", "model"))
dp4 = curve((4, 2), ("data", "model"), allreduce="layerwise")
print(json.dumps({"seq": seq, "dp4": dp4}))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        print(out.stderr[-2000:])
        raise RuntimeError("fig7 child failed")
    data = json.loads(out.stdout.strip().splitlines()[-1])
    seq, dp4 = data["seq"], data["dp4"]
    dev = max(abs(a - b) for a, b in zip(seq, dp4))
    print("# Fig7: sequential vs DP-4 loss curves (20 steps)")
    print("step  sequential  distributed")
    for i, (a, b) in enumerate(zip(seq, dp4)):
        print(f"{i:4d}  {a:10.6f}  {b:10.6f}")
    print(f"# max deviation: {dev:.2e}  (paper: 'losses are identical')")
    return [("fig7/max_loss_deviation", 0.0, dev),
            ("fig7/final_loss_seq", 0.0, seq[-1]),
            ("fig7/final_loss_dp4", 0.0, dp4[-1])]


if __name__ == "__main__":
    run()
