"""Roofline table over all dry-run cells (EXPERIMENTS.md §Roofline source).

Reads results/dryrun/*.json (produced by launch/dryrun.py on the 512-device
placeholder meshes) and emits the three-term roofline per cell plus CSV/MD
artifacts under results/.
"""
from __future__ import annotations

from pathlib import Path

from repro.roofline.analysis import format_csv, format_markdown, load_rows

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "results" / "dryrun"


def run():
    rows = load_rows(DRYRUN)
    if not rows:
        print("# no dry-run records found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return [("roofline/cells", 0.0, 0.0)]
    (ROOT / "results" / "roofline.csv").write_text(format_csv(rows))
    (ROOT / "results" / "roofline.md").write_text(format_markdown(rows))
    by_bottleneck = {}
    for r in rows:
        by_bottleneck.setdefault(r.bottleneck, []).append(r)
    print(f"# Roofline: {len(rows)} cells "
          f"(results/roofline.csv, results/roofline.md)")
    for k, v in sorted(by_bottleneck.items()):
        print(f"#   {k}-bound cells: {len(v)}")
    worst = sorted(rows, key=lambda r: r.est_mfu)[:8]
    print("# worst est-MFU cells:")
    for r in worst:
        print(f"#   {r.cell}: est_mfu={r.est_mfu:.2%} "
              f"bottleneck={r.bottleneck}")
    results = [("roofline/cells", 0.0, float(len(rows)))]
    for r in rows:
        results.append((f"roofline/{r.cell}/est_mfu", 0.0, r.est_mfu))
    return results


if __name__ == "__main__":
    run()
