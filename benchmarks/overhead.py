"""Paper §IV-B: MaTEx-TensorFlow's injected user-operations cost ~12%.

Our runtime injects collectives at *trace* time, so on one replica the
transparent step should compile to the same program as a hand-written
sequential step — measured here as wall-time overhead of
TransparentTrainer vs a raw jitted step on a single device.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.transparent import TransparentTrainer
from repro.models import registry
from repro.optim.optimizers import clip_by_global_norm, make_optimizer


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    cfg = get_config("stablelm-1.6b", smoke=True)
    bundle = registry.build(cfg)
    opt_cfg = OptimizerConfig(name="adam", lr=1e-2)
    opt = make_optimizer(opt_cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                   jnp.int32)}

    # raw sequential step (what a user would write, paper Fig. 3 right)
    params = bundle.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)

    @jax.jit
    def raw_step(params, opt_state, batch):
        loss, g = jax.value_and_grad(bundle.loss_fn)(params, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        g, _ = clip_by_global_norm(g, opt_cfg.grad_clip)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, loss

    def raw(params, opt_state):
        p, o, l = raw_step(params, opt_state, batch)
        return l
    t_raw = _time(raw, params, opt_state)

    # transparent runtime on a 1x1 mesh (wrapper cost, no communication)
    run_cfg = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 8),
                        mesh=MeshConfig(shape=(1, 1),
                                        axis_names=("data", "model")),
                        optimizer=opt_cfg)
    tr = TransparentTrainer(run_cfg, bundle.loss_fn, bundle.specs)
    state = tr.init(0)
    step = tr.step_fn(batch)

    def wrapped(state):
        s, m = step(state, batch)
        return s, m["loss"]

    # note: step donates its input; re-feed the new state each call
    for _ in range(3):
        state, _ = wrapped(state)

    t0 = time.perf_counter()
    iters = 20
    for _ in range(iters):
        state, l = wrapped(state)
    jax.block_until_ready(l)
    t_wrap = (time.perf_counter() - t0) / iters

    ovh = (t_wrap - t_raw) / t_raw
    print("# Overhead of the transparent runtime (1 replica, CPU)")
    print(f"raw step:          {t_raw*1e6:10.1f} us")
    print(f"transparent step:  {t_wrap*1e6:10.1f} us")
    print(f"overhead:          {ovh:+.1%}   (paper's user-op approach: ~+12%)")
    return [("overhead/raw_us", t_raw * 1e6, 0.0),
            ("overhead/transparent_us", t_wrap * 1e6, 0.0),
            ("overhead/fraction", 0.0, ovh)]


if __name__ == "__main__":
    run()
