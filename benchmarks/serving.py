"""Serving-engine benchmark: tokens/sec, TTFT, p50/p99 inter-token latency,
paged-vs-slotted KV-cache memory, and prefix-cache effectiveness.

    PYTHONPATH=src python benchmarks/serving.py [--arch qwen2.5-14b] \
        [--requests 16] [--batch 4] [--out BENCH_serving.json]
    PYTHONPATH=src python benchmarks/serving.py --smoke   # CI schema gate

Protocol: for each KV layout (paged, slotted) one warm-up pass populates
the jit caches (bucketed prefill + the single batched-decode executable),
then the measured pass serves a fresh queue of ragged-length requests
through the continuous-batching engine.  A third section serves a
shared-system-prompt workload (``--prefix-len`` common tokens + unique
tails) twice — prefix cache off ("cold") and on ("hit") — plus once on the
slotted pool, so the trajectory records the prefix cache's prefill-FLOPs
saving and any decode-throughput cost.  Results land in
``BENCH_serving.json`` so later PRs have a perf trajectory to beat.

Note on comparability: since the prefix-cache PR the paged measured pass
runs against pages cached by its own warm-up (realistic steady-state
traffic), so its ``prefill_tokens`` is far below the slotted section's;
``kv_bytes_saved_ratio`` is now peak-vs-peak (it used to divide the paged
peak by the slotted section's *static capacity*, mixing two protocols).
``compile_count`` is the engine-lifetime number of prefill traces —
bounded by the power-of-two bucketing, O(log max_seq_len).

Since the KV-layout PR the record also carries per-arch sections
(``record["archs"]``) for the newly paged families — deepseek-v2-lite
(MLA latent pages) and mixtral (ring-wrapped window pages) — each with the
same paged/slotted/prefix schema, so the layout seam's acceptance numbers
(paged peak below slotted, prefix_hit_rate) live in the trajectory.
Workload knobs are clamped per arch to its ``KVLayout`` (pages must tile
the attention window; sequences stay inside the window so the ring's lazy
growth can undercut the slotted pool's window-sized preallocation), and
serve capacity is provisioned one page above the workload maximum — the
"slotted pins the worst case, paged holds actuals" regime paging exists
for.

Since the observability PR every record also carries a ``phases`` section:
a separate *traced* pass (``ServeConfig(trace=True)`` — repro.obs spans
with ``block_until_ready`` fencing) attributing the engine-cycle wall to
host planning vs device prefill vs device decode vs glue.  Traced numbers
never enter the throughput trajectory (fencing costs tokens/sec); they
exist to explain it — e.g. whether the paged-vs-slotted gap on ROADMAP
open item 1 is host bookkeeping or kernel time.

Since the sampling/spec PR the record carries a ``spec`` section:
repetitive decode-dominated traffic (constant-token prompts drive the tiny
random models into argmax cycles the bigram drafter replays) served twice
— speculative decoding on and off, both at ``pipeline_depth=1`` — with
``accept_rate``, the spec-on/spec-off throughput ratio (``speedup``), and
output identity (spec only ever changes speed, never tokens).  ``--smoke``
gates on identity plus spec engagement, and on ``accept_rate > 0`` for the
headline arch.

Since the quantized-KV PR every pass summary is stamped with its
``kv_dtype`` and per-head paged archs carry a ``quantized`` section: the
measured workload re-served from int8 pages (per-row bf16 scales, dequant
fused into the paged-attention kernels) through both the jnp-oracle and
Pallas paths, with ``kv_bytes_peak_ratio`` vs the fp32 paged pass (smoke
gate <= 0.30x), ``same_budget_seq_ratio`` (>= 2x sequences admitted under
the same HBM budget) and kernel-on/off ``token_identical``.  MLA archs
omit the section (latent pages stay fp — the layout seam rejects int8).

Untraced passes *omit* the phase-derived keys entirely
(``repro.obs.TRACED_ONLY_KEYS``): with tracing off those fields were
emitted as literal ``0.0`` — reading as "zero host overhead" — so the
schema gate now asserts their absence instead of their presence.

``--smoke`` runs a seconds-scale workload *per smoke arch* (full, MLA and
windowed layouts) and asserts the emitted records still carry every
schema key, so drift breaks CI instead of the next PR's analysis; it also
writes one Perfetto-loadable Chrome trace per arch (``--trace-dir``) and
gates on trace-event schema validity, the pipeline span names
(``step.plan``/``step.submit``/``step.retire``), >= 95% phase coverage of
the engine-loop wall, and ``host_overhead_frac <= 0.25`` (the pipelined
submit/retire engine's bar — the synchronous loop sat at 0.37-0.49).
The ``run()`` hook returns harness-style ``(name, us_per_call, derived)``
rows.
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

DEFAULTS = dict(arch="qwen2.5-14b", requests=16, batch=4, prompt_len=16,
                max_new=12, page_size=8, prefix_len=64)

#: per-arch sections recorded alongside the headline arch (the families
#: the KV-layout seam brought onto the paged pool)
BENCH_ARCHS = ("deepseek-v2-lite-16b", "mixtral-8x22b")
#: archs the CI smoke gate exercises (one per page layout)
SMOKE_ARCHS = ("qwen2.5-14b",) + BENCH_ARCHS

#: schema gate: every emitted record must carry these (CI --smoke asserts);
#: 'paged'/'prefix'/'spec' are required only for archs with a paged decode
#: path ('spec' additionally needs the spec_serve capability)
REQUIRED_KEYS = ("arch", "requests", "slotted", "kv_bytes_saved_ratio",
                 "prefix", "spec", "quantized", "phases")
REQUIRED_SUMMARY_KEYS = ("tokens_per_sec", "ttft_p50_s", "itl_p50_s",
                         "kv_bytes_peak", "kv_bytes_slotted",
                         "prefill_tokens", "prefix_hit_rate",
                         "prefill_tokens_saved", "compile_count",
                         "kv_dtype")
REQUIRED_PREFIX_KEYS = ("hit", "cold", "slotted_tokens_per_sec",
                        "prefill_tokens_saved_ratio", "token_identical")
#: speculative-decoding workload section (repetitive traffic, spec on/off)
REQUIRED_SPEC_KEYS = ("on", "off", "accept_rate", "speedup",
                      "token_identical")
#: int8 quantized-KV workload section (per-head paged layouts only — MLA
#: latent pages stay fp): the same measured workload served from int8
#: pages, its memory ratios against the fp32 paged pass, and the
#: kernel-on/off identity of the quantized path
REQUIRED_QUANT_KEYS = ("int8", "kv_bytes_peak_ratio", "page_bytes_ratio",
                       "same_budget_seq_ratio", "token_identical")
#: CI bars for the quantized section: an int8 page (int8 rows + bf16
#: scales) must hold the measured peak at <= 0.30x the fp32 paged pass
#: (~0.28 on the hd=16 smoke shapes), and the same HBM budget must admit
#: >= 2x the concurrent sequences
QUANT_PEAK_GATE = 0.30
QUANT_ADMIT_GATE = 2.0
#: per-arch traced-attribution section (repro.obs): where the cycle goes;
#: ``prefill_kernel`` records whether the Pallas paged kernels (decode +
#: chunked prefill + verify) drove the pass — backend-selected, so the
#: trajectory's prefill_device_frac is attributable to the right path
REQUIRED_PHASE_KEYS = ("step_time_s", "plan_frac", "prefill_device_frac",
                       "decode_device_frac", "other_frac",
                       "host_overhead_frac", "coverage",
                       "decode_tokens_per_sec", "prefill_tokens_per_sec",
                       "prefill_kernel")
#: CI bar for host glue between device calls on the traced smoke pass —
#: the number the pipelined submit/retire refactor drives down (was
#: 0.49/0.45/0.37 across the smoke archs on the synchronous engine)
HOST_OVERHEAD_GATE = 0.25
#: pipeline span names the emitted Chrome trace must carry (smoke gate):
#: a refactor that silently stops emitting the section spans would void
#: the attribution math without failing any numeric bar
REQUIRED_SPAN_NAMES = ("step", "step.plan", "step.submit", "step.retire")


def _arch_kw(arch, kw):
    """Clamp workload knobs to the arch's KVLayout: ring pages must tile
    the attention window, and sequences stay inside it so the ring's lazy
    growth (plus prefix sharing) can undercut the slotted pool."""
    from repro.configs import get_config
    from repro.models import registry

    layout = registry.build(get_config(arch, smoke=True)).kv_layout
    kw = dict(kw, arch=arch)
    if layout is not None and layout.window:
        w = layout.window
        kw["page_size"] = min(kw["page_size"], layout.max_page_size())
        kw["prompt_len"] = min(kw["prompt_len"], max(w // 2, 1))
        kw["max_new"] = min(kw["max_new"], max(w // 4, 1))
        kw["prefix_len"] = min(kw["prefix_len"], w)
    return kw


def _untraced(summary):
    """Strip phase-derived keys from an *untraced* pass's summary.  With
    tracing off per-phase time does not exist, so those fields are
    structurally ``0.0`` — emitting them into BENCH_serving.json read as
    "host overhead is zero" / "decode throughput is zero".  Only the
    fenced attribution pass (:func:`_traced_attribution`) reports phase
    data; the schema gate asserts the absence."""
    from repro.obs import TRACED_ONLY_KEYS
    return {k: v for k, v in summary.items() if k not in TRACED_ONLY_KEYS}


def _make_engine(arch, batch, max_seq, max_new, kv_layout, page_size,
                 **serve_kw):
    from repro.configs import ServeConfig, get_config
    from repro.serving import ServingEngine

    cfg = get_config(arch, smoke=True)
    scfg = ServeConfig(max_batch=batch, max_queue=64, max_seq_len=max_seq,
                       max_new_tokens=max_new, max_prefills_per_step=2,
                       decode_steps=serve_kw.pop("decode_steps", 4),
                       kv_layout=kv_layout,
                       page_size=page_size, **serve_kw)
    return cfg, ServingEngine(cfg, scfg, seed=0)


def _serve_once(arch, requests, batch, prompt_len, max_new, kv_layout,
                page_size):
    import numpy as np

    # serve capacity one page above the workload maximum (real deployments
    # provision headroom; the slotted pool pins it, the paged pool holds
    # actual lengths — the gap is the paging win).  Page headroom beyond
    # the live worst case: refcount-0 cached pages survive between passes,
    # so the measured pass serves repeat traffic out of the prefix cache
    # (worst-case-only provisioning reclaims every cached page before its
    # prompt comes around again)
    max_seq = prompt_len + max_new + page_size
    pages = 3 * batch * (-(-max_seq // page_size)) + 1
    cfg, engine = _make_engine(arch, batch, max_seq, max_new,
                               kv_layout, page_size, num_pages=pages)
    rng = np.random.default_rng(0)
    lengths = rng.integers(max(prompt_len // 2, 1), prompt_len + 1,
                           size=requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)) for l in lengths]
    # warm-up: compile the prefill buckets + the decode step (and, paged,
    # seed the prefix cache — the measured passes are steady-state traffic:
    # every block is already cached, so each pass repeats identical work)
    engine.generate(prompts, max_new)
    best = None
    for _ in range(5):                    # best-of-5: the box is shared
        engine.metrics.reset()
        engine.results.clear()
        out = engine.generate(prompts, max_new)
        assert len(out) == requests and all(len(t) == max_new for t in out)
        s = _untraced(engine.metrics.summary())
        s["compile_count"] = engine.prefill_compiles  # lifetime, not window
        s["kv_dtype"] = "fp32"          # the baseline passes store fp pages
        if best is None or s["tokens_per_sec"] > best["tokens_per_sec"]:
            best = s
    return engine.paged, best


def _traced_attribution(arch, requests, batch, prompt_len, max_new,
                        page_size, trace_path=None):
    """One *traced* pass (``ServeConfig(trace=True)``: repro.obs spans +
    ``block_until_ready`` fencing): where the engine cycle's wall time
    goes — host planning vs device prefill vs device decode vs glue.

    Deliberately separate from the measured passes: fencing serializes
    dispatch and costs throughput, so traced numbers feed the attribution
    fractions only, never the tokens_per_sec trajectory.  Best-of-3
    windows, same policy (and same reason) as the measured passes'
    best-of-5: the box is shared, and a scheduler interruption between
    fenced dispatches lands entirely in host-attributed time, so the
    lowest-glue window is the closest to the engine's true overhead.
    When ``trace_path`` is set the Chrome trace JSON (Perfetto-loadable)
    of the last window is written there too."""
    import numpy as np
    from repro.obs import HOST_OVERHEAD_FRAC, phase_coverage

    max_seq = prompt_len + max_new + page_size
    pages = 3 * batch * (-(-max_seq // page_size)) + 1
    cfg, engine = _make_engine(arch, batch, max_seq, max_new, "auto",
                               page_size, num_pages=pages, trace=True)
    rng = np.random.default_rng(0)
    lengths = rng.integers(max(prompt_len // 2, 1), prompt_len + 1,
                           size=requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)) for l in lengths]
    engine.generate(prompts, max_new)     # compile warm-up
    best = None
    for _ in range(3):
        engine.tracer.reset()             # measured traced window only
        engine.metrics.reset()
        engine.results.clear()
        engine.generate(prompts, max_new)
        s = engine.metrics.summary()
        st = s["step_time_s"] or 1.0
        out = {
            "step_time_s": s["step_time_s"],
            "plan_frac": s["plan_time_s"] / st,
            "prefill_device_frac": s["prefill_time_s"] / st,
            "decode_device_frac": s["decode_time_s"] / st,
            "other_frac": s["other_time_s"] / st,
            HOST_OVERHEAD_FRAC: s[HOST_OVERHEAD_FRAC],
            "coverage": phase_coverage(engine.tracer),
            "decode_tokens_per_sec": s["decode_tokens_per_sec"],
            "prefill_tokens_per_sec": s["prefill_tokens_per_sec"],
            "prefill_kernel": bool(engine.paged_kernel),
        }
        if best is None or out[HOST_OVERHEAD_FRAC] < best[HOST_OVERHEAD_FRAC]:
            best = out
    if trace_path:
        engine.save_trace(trace_path)
    return best


def _prefix_workload(arch, requests, batch, prefix_len, max_new, page_size):
    """Shared-system-prompt traffic: cold vs prefix-cache vs slotted.

    Runs prefill-dominated (short generation budget): the regime prefix
    caching targets — long shared prompts, few output tokens (RAG,
    classification, templated chat turns) — so the recorded throughput
    ordering reflects the prefill-FLOPs saving, not decode-kernel deltas.
    """
    import numpy as np

    max_new = min(max_new, 4)
    tail = max(prefix_len // 4, 4)
    max_seq = prefix_len + tail + max_new
    rng = np.random.default_rng(1)
    from repro.configs import get_config
    vocab = get_config(arch, smoke=True).vocab_size

    def workload(r):
        system = list(r.integers(0, vocab, (prefix_len,)))
        return [system + list(r.integers(0, vocab, (tail,)))
                for _ in range(requests)]

    prompts = workload(rng)
    # warm-up shares a *different* system prompt: compiles (miss + hit
    # buckets) land in the jit cache without seeding any block the measured
    # prompts could match, so the measured pass shows in-batch sharing only
    warm = workload(rng)

    def serve(kv_layout, **kw):
        """Warm-up pass, then best-of-5 measured passes (the box is shared;
        per-pass elapsed is seconds-scale and scheduler noise swings it
        30%+).  The prefix index is cleared before every measured pass, so
        each shows *in-batch* sharing only and all five are identical work
        — best-of is legitimate."""
        _, eng = _make_engine(arch, batch, max_seq, max_new, kv_layout,
                              page_size, **kw)
        eng.generate(warm, max_new)
        best = None
        for _ in range(5):
            if eng.paged:
                eng.pool.clear_prefix_cache()
            eng.metrics.reset()
            eng.results.clear()
            outs = eng.generate(prompts, max_new)
            s = _untraced(eng.metrics.summary())
            s["compile_count"] = eng.prefill_compiles
            if best is None or s["tokens_per_sec"] > best[1]["tokens_per_sec"]:
                best = (outs, s)
        return best

    out_hit, hit = serve("paged", enable_prefix_cache=True)
    out_cold, cold = serve("paged", enable_prefix_cache=False)
    _, slotted = serve("slotted")
    saved = 1.0 - hit["prefill_tokens"] / max(cold["prefill_tokens"], 1)
    return {
        "requests": requests, "prefix_len": prefix_len, "tail_len": tail,
        "hit": hit, "cold": cold,
        "slotted_tokens_per_sec": slotted["tokens_per_sec"],
        "prefill_tokens_saved_ratio": saved,
        "token_identical": out_hit == out_cold,
    }


def _spec_workload(arch, batch, page_size, spec_tokens=8, max_new=32,
                   passes=5):
    """Speculative decoding on repetitive decode-dominated traffic: the
    regime n-gram drafting targets (templated output, code, retrieval
    echoes — continuations the history already contains).

    Constant-token prompts push the tiny random models into short argmax
    cycles the bigram drafter replays, so acceptance is non-trivial and
    the recorded ``speedup`` (spec-on vs spec-off tokens/sec) reflects
    verify-one-forward replacing several decode dispatches.  Both arms run
    ``pipeline_depth=1``: at depth 2 a speculating slot alternates
    verify/idle cycles (the host needs the retired history to draft), so
    depth-1 isolates the drafting win from pipelining effects.  Both arms
    also run ``decode_steps=1`` and ``batch <= 2`` — the interactive
    low-ITL regime that speculation targets, where the baseline pays one
    dispatch per token.  At ``decode_steps=4`` the engine already
    amortises dispatches 4x inside the fused multi-step scan, and at
    high batch it amortises one decode dispatch across every slot while
    verify forwards run one per speculating slot (the classic
    spec-decode crossover: a win for interactive traffic, a wash or
    loss for saturated batch throughput) — in either regime the
    recorded ratio would measure dispatch amortisation, not drafting.
    Output identity between the arms is part of the record — spec only
    ever changes speed."""
    import numpy as np
    from repro.configs import get_config

    vocab = get_config(arch, smoke=True).vocab_size
    batch = min(batch, 2)
    requests = 2 * batch
    prompt_len = 16
    prompts = [[(1 + i) % vocab] * prompt_len for i in range(requests)]
    max_seq = prompt_len + max_new + page_size
    pages = 3 * batch * (-(-max_seq // page_size)) + 1

    def serve(enable):
        _, eng = _make_engine(arch, batch, max_seq, max_new, "paged",
                              page_size, num_pages=pages, pipeline_depth=1,
                              decode_steps=1, enable_spec=enable,
                              spec_tokens=spec_tokens)
        eng.generate(prompts, max_new)        # compile + cache warm-up
        best = None
        for _ in range(passes):
            eng.metrics.reset()
            eng.results.clear()
            outs = eng.generate(prompts, max_new)
            s = _untraced(eng.metrics.summary())
            if best is None or s["tokens_per_sec"] > best[1]["tokens_per_sec"]:
                best = (outs, s)
        return best

    out_on, on = serve(True)
    out_off, off = serve(False)
    return {
        "requests": requests, "prompt_len": prompt_len, "max_new": max_new,
        "spec_tokens": spec_tokens, "on": on, "off": off,
        "accept_rate": on["accept_rate"],
        "speedup": on["tokens_per_sec"] / max(off["tokens_per_sec"], 1e-9),
        "token_identical": out_on == out_off,
    }


def _quantized_workload(arch, requests, batch, prompt_len, max_new,
                        page_size, fp32_peak):
    """Int8 quantized-KV section: the measured workload re-served from
    int8 pages (per-row bf16 scales, dequant fused into the attention
    math), once through the jnp oracle path and once through the Pallas
    kernels (interpret off-TPU) — quantization is part of the written
    page, so the two must agree token for token.

    Memory evidence comes from the pool itself: ``kv_bytes_peak_ratio``
    divides the int8 pass's measured peak by the fp32 paged pass's
    (``fp32_peak``), ``page_bytes_ratio`` is the per-page storage ratio,
    and ``same_budget_seq_ratio`` is how many more worst-case sequences
    the same HBM byte budget admits — the oversubscription headroom
    quantization buys."""
    import numpy as np
    from repro.configs import get_config

    max_seq = prompt_len + max_new + page_size
    pages = 3 * batch * (-(-max_seq // page_size)) + 1
    # the exact workload _serve_once measured on the fp32 pools
    rng = np.random.default_rng(0)
    lengths = rng.integers(max(prompt_len // 2, 1), prompt_len + 1,
                           size=requests)
    vocab = get_config(arch, smoke=True).vocab_size
    prompts = [rng.integers(0, vocab, (int(l),)) for l in lengths]

    def serve(use_pallas):
        cfg, eng = _make_engine(arch, batch, max_seq, max_new, "paged",
                                page_size, num_pages=pages,
                                kv_dtype="int8", use_pallas=use_pallas)
        eng.generate(prompts, max_new)        # compile + cache warm-up
        best = None
        for _ in range(5):
            eng.metrics.reset()
            eng.results.clear()
            outs = eng.generate(prompts, max_new)
            s = _untraced(eng.metrics.summary())
            s["kv_dtype"] = "int8"
            if best is None or s["tokens_per_sec"] > best[1]["tokens_per_sec"]:
                best = (outs, s)
        return best + (eng.pool,)

    out_ref, int8, pool = serve(False)
    out_kern, int8_kern, _ = serve(True)
    # worst-case sequences one HBM byte budget admits, fp32 vs int8 pages:
    # both pools page identically (same table geometry), so the ratio is
    # pure bytes-per-page — measured off the live pool, not assumed
    budget = (pages - 1) * pool.page_bytes_fp32
    seq_pages = -(-max_seq // page_size)
    fp32_seqs = budget // (seq_pages * pool.page_bytes_fp32)
    int8_seqs = budget // (seq_pages * pool.page_bytes)
    return {
        "requests": requests, "prompt_len": prompt_len, "max_new": max_new,
        "int8": int8, "int8_kernel": int8_kern,
        "kv_bytes_peak_ratio": (int8["kv_bytes_peak"] / fp32_peak
                                if fp32_peak else 0.0),
        "page_bytes_ratio": pool.page_bytes / pool.page_bytes_fp32,
        "same_budget_seq_ratio": int8_seqs / max(fp32_seqs, 1),
        "token_identical": out_ref == out_kern,
    }


def _bench(trace_path=None, **kw):
    """{'paged': summary, 'slotted': summary, 'kv_bytes_saved_ratio': x,
    'prefix': {...}, 'spec': {...}, 'phases': {...}}.

    Archs without a paged decode path (recurrent families — no KVLayout)
    bench the slotted layout only: no 'paged'/'prefix'/'spec' section,
    ratio 0.  'phases' always runs (a separate traced pass — see
    ``_traced_attribution``)."""
    from repro.configs import get_config
    from repro.models import registry

    caps = registry.build(get_config(kw["arch"], smoke=True)).capabilities()
    paged_ok = "paged_serve" in caps
    record = {}
    for layout in (("paged", "slotted") if paged_ok else ("slotted",)):
        is_paged, s = _serve_once(kw["arch"], kw["requests"], kw["batch"],
                                  kw["prompt_len"], kw["max_new"],
                                  layout, kw["page_size"])
        assert is_paged == (layout == "paged")
        record[layout] = s
    record["kv_bytes_saved_ratio"] = 0.0
    record["prefix"] = {}
    if paged_ok:
        # peak-vs-peak: what the paged pool held at its high-water mark vs
        # what the slotted pool held at its (constant) one.  (The previous
        # formula divided the paged peak by the *slotted-equivalent
        # capacity* reported inside the paged section — a protocol mix
        # that understated the saving.)
        peak = record["paged"]["kv_bytes_peak"]
        wall = record["slotted"]["kv_bytes_peak"]
        record["kv_bytes_saved_ratio"] = (1.0 - peak / wall) if wall else 0.0
        record["prefix"] = _prefix_workload(
            kw["arch"], kw["requests"], kw["batch"], kw["prefix_len"],
            kw["max_new"], kw["page_size"])
    record["spec"] = {}
    if paged_ok and "spec_serve" in caps:
        record["spec"] = _spec_workload(kw["arch"], kw["batch"],
                                        kw["page_size"])
    # int8 quantized-KV section: per-head paged layouts only (MLA latent
    # pages stay fp — the layout seam rejects the combination)
    record["quantized"] = {}
    layout = registry.build(get_config(kw["arch"], smoke=True)).kv_layout
    if paged_ok and layout is not None and layout.name != "latent":
        record["quantized"] = _quantized_workload(
            kw["arch"], kw["requests"], kw["batch"], kw["prompt_len"],
            kw["max_new"], kw["page_size"],
            fp32_peak=record["paged"]["kv_bytes_peak"])
    record["phases"] = _traced_attribution(
        kw["arch"], kw["requests"], kw["batch"], kw["prompt_len"],
        kw["max_new"], kw["page_size"], trace_path=trace_path)
    return record


def check_schema(record):
    """Raise AssertionError when the emitted record drifts from the schema
    later analysis (and the acceptance trajectory) depends on.  Slotted-only
    archs (no paged decode path) legitimately omit 'paged' and carry an
    empty 'prefix' section.  Per-arch sections under 'archs' (the KV-layout
    families) carry the same schema recursively."""
    for k in REQUIRED_KEYS:
        assert k in record, f"BENCH_serving.json schema drift: missing {k!r}"
    assert ("paged" in record) == bool(record["prefix"]), \
        "schema drift: paged section and prefix workload must co-occur"
    from repro.obs import TRACED_ONLY_KEYS
    for section in ("paged", "slotted"):
        if record.get(section):
            for k in REQUIRED_SUMMARY_KEYS:
                assert k in record[section], \
                    f"schema drift: missing {section}.{k}"
            for k in TRACED_ONLY_KEYS:
                assert k not in record[section], \
                    f"schema drift: untraced {section}.{k} would read as " \
                    "a measured zero — phase data belongs to 'phases' only"
    if record.get("prefix"):
        for k in REQUIRED_PREFIX_KEYS:
            assert k in record["prefix"], f"schema drift: missing prefix.{k}"
    if record.get("spec"):
        for k in REQUIRED_SPEC_KEYS:
            assert k in record["spec"], f"schema drift: missing spec.{k}"
        assert "drafted_tokens" in record["spec"]["on"], \
            "schema drift: spec.on summary lost the drafted_tokens counter"
    if record.get("quantized"):
        for k in REQUIRED_QUANT_KEYS:
            assert k in record["quantized"], \
                f"schema drift: missing quantized.{k}"
        assert record["quantized"]["int8"].get("kv_dtype") == "int8", \
            "schema drift: quantized.int8 summary lost its kv_dtype stamp"
    for k in REQUIRED_PHASE_KEYS:
        assert k in record["phases"], f"schema drift: missing phases.{k}"
    for arch, sub in record.get("archs", {}).items():
        check_schema(sub)


def run(**overrides):
    """Harness hook: [(name, us_per_call, derived), ...]."""
    kw = {**DEFAULTS, **overrides}
    r = _bench(**kw)
    s = r["slotted"]
    p = r.get("paged", s)
    px = r.get("prefix") or {}
    return [
        ("serving_tokens_per_sec", 0.0, p["tokens_per_sec"]),
        ("serving_tokens_per_sec_slotted", 0.0, s["tokens_per_sec"]),
        ("serving_ttft_p50", p["ttft_p50_s"] * 1e6, p["ttft_p50_s"]),
        ("serving_ttft_p99", p["ttft_p99_s"] * 1e6, p["ttft_p99_s"]),
        ("serving_itl_p50", p["itl_p50_s"] * 1e6, p["itl_p50_s"]),
        ("serving_itl_p99", p["itl_p99_s"] * 1e6, p["itl_p99_s"]),
        ("serving_kv_bytes_peak_paged", 0.0, p["kv_bytes_peak"]),
        ("serving_kv_bytes_peak_slotted", 0.0, s["kv_bytes_peak"]),
        ("serving_kv_bytes_saved_ratio", 0.0, r["kv_bytes_saved_ratio"]),
        ("serving_prefix_hit_rate", 0.0,
         px.get("hit", {}).get("prefix_hit_rate", 0.0)),
        ("serving_prefill_tokens_saved_ratio", 0.0,
         px.get("prefill_tokens_saved_ratio", 0.0)),
        ("serving_spec_accept_rate", 0.0,
         (r.get("spec") or {}).get("accept_rate", 0.0)),
        ("serving_spec_speedup", 0.0,
         (r.get("spec") or {}).get("speedup", 0.0)),
        ("serving_int8_kv_peak_ratio", 0.0,
         (r.get("quantized") or {}).get("kv_bytes_peak_ratio", 0.0)),
        ("serving_int8_same_budget_seq_ratio", 0.0,
         (r.get("quantized") or {}).get("same_budget_seq_ratio", 0.0)),
        ("serving_prefill_compile_count", 0.0, p["compile_count"]),
        ("serving_plan_time_frac", 0.0, r["phases"]["plan_frac"]),
        ("serving_decode_device_frac", 0.0,
         r["phases"]["decode_device_frac"]),
        ("serving_host_overhead_frac", 0.0,
         r["phases"]["host_overhead_frac"]),
        ("serving_phase_coverage", 0.0, r["phases"]["coverage"]),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=DEFAULTS["arch"])
    ap.add_argument("--requests", type=int, default=DEFAULTS["requests"])
    ap.add_argument("--batch", type=int, default=DEFAULTS["batch"])
    ap.add_argument("--prompt-len", type=int, default=DEFAULTS["prompt_len"])
    ap.add_argument("--max-new", type=int, default=DEFAULTS["max_new"])
    ap.add_argument("--page-size", type=int, default=DEFAULTS["page_size"])
    ap.add_argument("--prefix-len", type=int, default=DEFAULTS["prefix_len"],
                    help="shared system-prompt length of the prefix-cache "
                         "workload section")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run + schema assertion (CI gate); "
                         "does not overwrite BENCH_serving.json")
    ap.add_argument("--trace-dir", default=".",
                    help="where --smoke writes its per-arch Chrome traces "
                         "(smoke_trace_<arch>.json, Perfetto-loadable; "
                         "CI uploads them as artifacts)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_serving.json"))
    args = ap.parse_args()
    kw = dict(arch=args.arch, requests=args.requests, batch=args.batch,
              prompt_len=args.prompt_len, max_new=args.max_new,
              page_size=args.page_size, prefix_len=args.prefix_len)
    if args.smoke:
        kw.update(requests=6, batch=2, prompt_len=8, max_new=4,
                  page_size=4, prefix_len=16)
        # one workload per page layout: full (contiguous k/v), MLA
        # (latent), windowed (ring) — schema asserted for each, plus the
        # trace gate: the emitted Chrome trace must be schema-valid
        # (every event carries ph/ts/pid/tid) and the engine-track section
        # spans must cover >= 95% of the step wall (the attribution bar)
        Path(args.trace_dir).mkdir(parents=True, exist_ok=True)
        for arch in SMOKE_ARCHS:
            akw = _arch_kw(arch, kw)
            tp = Path(args.trace_dir) / f"smoke_trace_{arch}.json"
            r = _bench(trace_path=str(tp), **akw)
            record = {"arch": arch, "requests": akw["requests"], **r}
            check_schema(record)
            evs = json.loads(tp.read_text())["traceEvents"]
            assert evs and all({"ph", "ts", "pid", "tid"} <= set(e)
                               for e in evs), \
                f"trace schema drift in {tp}"
            names = {e["name"] for e in evs}
            missing = [n for n in REQUIRED_SPAN_NAMES if n not in names]
            assert not missing, \
                f"trace span drift in {tp}: missing {missing} — the " \
                "pipeline sections stopped being traced"
            ph = record["phases"]
            assert ph["coverage"] >= 0.95, \
                f"phase spans cover {ph['coverage']:.1%} < 95% of the " \
                f"engine-loop wall [{arch}]"
            assert ph["host_overhead_frac"] <= HOST_OVERHEAD_GATE, \
                f"host_overhead_frac={ph['host_overhead_frac']:.2f} > " \
                f"{HOST_OVERHEAD_GATE} [{arch}]: host glue between device " \
                "calls regressed past the pipelined-engine bar"
            sp = record["spec"]
            if sp:
                assert sp["token_identical"], \
                    f"spec changed tokens [{arch}] — verification must " \
                    "replay the engine's own sampler exactly"
                assert sp["on"]["drafted_tokens"] > 0, \
                    f"spec never engaged on the repetitive workload [{arch}]"
                if arch == SMOKE_ARCHS[0]:
                    assert sp["accept_rate"] > 0, \
                        "spec accepted nothing on the repetitive workload " \
                        f"[{arch}] — verify/accept plumbing is broken"
                    # headline arch must actually profit: one batched
                    # verify emitting ~accept_rate * spec_tokens tokens
                    # has to beat per-token decode dispatches.  Ring
                    # archs clamp drafts to 1 (cell aliasing) and are
                    # recorded but not gated.
                    assert sp["speedup"] >= 1.0, \
                        f"spec-on slower than spec-off [{arch}]: " \
                        f"{sp['speedup']:.2f}x on the repetitive workload"
            qz = record["quantized"]
            if qz:
                assert qz["token_identical"], \
                    f"int8 kernel-on vs kernel-off token drift [{arch}] — " \
                    "fused dequant diverged from the jnp oracle"
                assert qz["kv_bytes_peak_ratio"] <= QUANT_PEAK_GATE, \
                    f"int8 kv_bytes_peak at " \
                    f"{qz['kv_bytes_peak_ratio']:.3f}x fp32 > " \
                    f"{QUANT_PEAK_GATE} [{arch}] — the quantized page " \
                    "layout stopped paying for itself"
                assert qz["same_budget_seq_ratio"] >= QUANT_ADMIT_GATE, \
                    f"int8 admits only {qz['same_budget_seq_ratio']:.1f}x " \
                    f"sequences under the fp32 byte budget [{arch}] " \
                    f"(gate {QUANT_ADMIT_GATE}x)"
            hit = (record["prefix"] or {}).get("hit", {})
            print(f"smoke OK [{arch}]: schema intact; "
                  f"prefix_hit_rate={hit.get('prefix_hit_rate', 0.0):.2f} "
                  f"kv_saved={record['kv_bytes_saved_ratio']:.2f} "
                  f"phase_coverage={ph['coverage']:.2f} "
                  f"decode_frac={ph['decode_device_frac']:.2f} "
                  f"prefill_frac={ph['prefill_device_frac']:.2f} "
                  f"prefill_kernel={ph['prefill_kernel']} "
                  f"host_overhead={ph['host_overhead_frac']:.2f} "
                  f"accept_rate={(sp or {}).get('accept_rate', 0.0):.2f} "
                  f"spec_speedup={(sp or {}).get('speedup', 0.0):.2f} "
                  f"int8_peak_ratio="
                  f"{(qz or {}).get('kv_bytes_peak_ratio', 0.0):.2f} "
                  f"int8_admits="
                  f"{(qz or {}).get('same_budget_seq_ratio', 0.0):.1f}x "
                  f"(trace: {tp})")
        return
    record = {
        "arch": kw["arch"], "smoke": True, "requests": kw["requests"],
        "batch_slots": kw["batch"], "prompt_len": kw["prompt_len"],
        "max_new": kw["max_new"], "page_size": kw["page_size"],
        **_bench(**kw),
    }
    # per-arch sections for the KV-layout families (latent + ring pages)
    record["archs"] = {}
    for arch in BENCH_ARCHS:
        if arch == kw["arch"]:
            continue
        akw = _arch_kw(arch, kw)
        sub = _bench(**akw)
        record["archs"][arch] = {
            "arch": arch, "requests": akw["requests"],
            "prompt_len": akw["prompt_len"], "max_new": akw["max_new"],
            "page_size": akw["page_size"], **sub,
        }
    check_schema(record)
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
