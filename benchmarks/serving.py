"""Serving-engine benchmark: tokens/sec, TTFT, p50/p99 inter-token latency,
paged-vs-slotted KV-cache memory, and prefix-cache effectiveness.

    PYTHONPATH=src python benchmarks/serving.py [--arch qwen2.5-14b] \
        [--requests 16] [--batch 4] [--out BENCH_serving.json]
    PYTHONPATH=src python benchmarks/serving.py --smoke   # CI schema gate

Protocol: for each KV layout (paged, slotted) one warm-up pass populates
the jit caches (bucketed prefill + the single batched-decode executable),
then the measured pass serves a fresh queue of ragged-length requests
through the continuous-batching engine.  A third section serves a
shared-system-prompt workload (``--prefix-len`` common tokens + unique
tails) twice — prefix cache off ("cold") and on ("hit") — plus once on the
slotted pool, so the trajectory records the prefix cache's prefill-FLOPs
saving and any decode-throughput cost.  Results land in
``BENCH_serving.json`` so later PRs have a perf trajectory to beat.

Note on comparability: since the prefix-cache PR the paged measured pass
runs against pages cached by its own warm-up (realistic steady-state
traffic), so its ``prefill_tokens`` is far below the slotted section's;
``kv_bytes_saved_ratio`` is now peak-vs-peak (it used to divide the paged
peak by the slotted section's *static capacity*, mixing two protocols).
``compile_count`` is the engine-lifetime number of prefill traces —
bounded by the power-of-two bucketing, O(log max_seq_len).

``--smoke`` runs a seconds-scale workload and asserts the emitted record
still carries every schema key, so drift breaks CI instead of the next
PR's analysis.  The ``run()`` hook returns harness-style
``(name, us_per_call, derived)`` rows.
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

DEFAULTS = dict(arch="qwen2.5-14b", requests=16, batch=4, prompt_len=16,
                max_new=12, page_size=8, prefix_len=64)

#: schema gate: every emitted record must carry these (CI --smoke asserts);
#: 'paged'/'prefix' are required only for archs with a paged decode path
REQUIRED_KEYS = ("arch", "requests", "slotted", "kv_bytes_saved_ratio",
                 "prefix")
REQUIRED_SUMMARY_KEYS = ("tokens_per_sec", "ttft_p50_s", "itl_p50_s",
                         "kv_bytes_peak", "kv_bytes_slotted",
                         "prefill_tokens", "prefix_hit_rate",
                         "prefill_tokens_saved", "compile_count")
REQUIRED_PREFIX_KEYS = ("hit", "cold", "slotted_tokens_per_sec",
                        "prefill_tokens_saved_ratio", "token_identical")


def _make_engine(arch, batch, max_seq, max_new, kv_layout, page_size,
                 **serve_kw):
    from repro.configs import ServeConfig, get_config
    from repro.serving import ServingEngine

    cfg = get_config(arch, smoke=True)
    scfg = ServeConfig(max_batch=batch, max_queue=64, max_seq_len=max_seq,
                       max_new_tokens=max_new, max_prefills_per_step=2,
                       decode_steps=4, kv_layout=kv_layout,
                       page_size=page_size, **serve_kw)
    return cfg, ServingEngine(cfg, scfg, seed=0)


def _serve_once(arch, requests, batch, prompt_len, max_new, kv_layout,
                page_size):
    import numpy as np

    # page headroom beyond the live worst case: refcount-0 cached pages
    # survive between passes, so the measured pass serves repeat traffic
    # out of the prefix cache (worst-case-only provisioning reclaims every
    # cached page before its prompt comes around again)
    max_seq = prompt_len + max_new
    pages = 3 * batch * (-(-max_seq // page_size)) + 1
    cfg, engine = _make_engine(arch, batch, max_seq, max_new,
                               kv_layout, page_size, num_pages=pages)
    rng = np.random.default_rng(0)
    lengths = rng.integers(max(prompt_len // 2, 1), prompt_len + 1,
                           size=requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)) for l in lengths]
    # warm-up: compile the prefill buckets + the decode step (and, paged,
    # seed the prefix cache — the measured passes are steady-state traffic:
    # every block is already cached, so each pass repeats identical work)
    engine.generate(prompts, max_new)
    best = None
    for _ in range(5):                    # best-of-5: the box is shared
        engine.metrics.reset()
        engine.results.clear()
        out = engine.generate(prompts, max_new)
        assert len(out) == requests and all(len(t) == max_new for t in out)
        s = engine.metrics.summary()
        s["compile_count"] = engine.prefill_compiles  # lifetime, not window
        if best is None or s["tokens_per_sec"] > best["tokens_per_sec"]:
            best = s
    return engine.paged, best


def _prefix_workload(arch, requests, batch, prefix_len, max_new, page_size):
    """Shared-system-prompt traffic: cold vs prefix-cache vs slotted.

    Runs prefill-dominated (short generation budget): the regime prefix
    caching targets — long shared prompts, few output tokens (RAG,
    classification, templated chat turns) — so the recorded throughput
    ordering reflects the prefill-FLOPs saving, not decode-kernel deltas.
    """
    import numpy as np

    max_new = min(max_new, 4)
    tail = max(prefix_len // 4, 4)
    max_seq = prefix_len + tail + max_new
    rng = np.random.default_rng(1)
    from repro.configs import get_config
    vocab = get_config(arch, smoke=True).vocab_size

    def workload(r):
        system = list(r.integers(0, vocab, (prefix_len,)))
        return [system + list(r.integers(0, vocab, (tail,)))
                for _ in range(requests)]

    prompts = workload(rng)
    # warm-up shares a *different* system prompt: compiles (miss + hit
    # buckets) land in the jit cache without seeding any block the measured
    # prompts could match, so the measured pass shows in-batch sharing only
    warm = workload(rng)

    def serve(kv_layout, **kw):
        """Warm-up pass, then best-of-5 measured passes (the box is shared;
        per-pass elapsed is seconds-scale and scheduler noise swings it
        30%+).  The prefix index is cleared before every measured pass, so
        each shows *in-batch* sharing only and all five are identical work
        — best-of is legitimate."""
        _, eng = _make_engine(arch, batch, max_seq, max_new, kv_layout,
                              page_size, **kw)
        eng.generate(warm, max_new)
        best = None
        for _ in range(5):
            if eng.paged:
                eng.pool.clear_prefix_cache()
            eng.metrics.reset()
            eng.results.clear()
            outs = eng.generate(prompts, max_new)
            s = eng.metrics.summary()
            s["compile_count"] = eng.prefill_compiles
            if best is None or s["tokens_per_sec"] > best[1]["tokens_per_sec"]:
                best = (outs, s)
        return best

    out_hit, hit = serve("paged", enable_prefix_cache=True)
    out_cold, cold = serve("paged", enable_prefix_cache=False)
    _, slotted = serve("slotted")
    saved = 1.0 - hit["prefill_tokens"] / max(cold["prefill_tokens"], 1)
    return {
        "requests": requests, "prefix_len": prefix_len, "tail_len": tail,
        "hit": hit, "cold": cold,
        "slotted_tokens_per_sec": slotted["tokens_per_sec"],
        "prefill_tokens_saved_ratio": saved,
        "token_identical": out_hit == out_cold,
    }


def _bench(**kw):
    """{'paged': summary, 'slotted': summary, 'kv_bytes_saved_ratio': x,
    'prefix': {...}}.

    Archs without a paged decode path (recurrent / MLA / windowed) bench
    the slotted layout only — no 'paged'/'prefix' section, ratio 0."""
    from repro.configs import get_config
    from repro.models import registry

    paged_ok = registry.build(
        get_config(kw["arch"], smoke=True)).paged_decode_fn is not None
    record = {}
    for layout in (("paged", "slotted") if paged_ok else ("slotted",)):
        is_paged, s = _serve_once(kw["arch"], kw["requests"], kw["batch"],
                                  kw["prompt_len"], kw["max_new"],
                                  layout, kw["page_size"])
        assert is_paged == (layout == "paged")
        record[layout] = s
    record["kv_bytes_saved_ratio"] = 0.0
    record["prefix"] = {}
    if paged_ok:
        # peak-vs-peak: what the paged pool held at its high-water mark vs
        # what the slotted pool held at its (constant) one.  (The previous
        # formula divided the paged peak by the *slotted-equivalent
        # capacity* reported inside the paged section — a protocol mix
        # that understated the saving.)
        peak = record["paged"]["kv_bytes_peak"]
        wall = record["slotted"]["kv_bytes_peak"]
        record["kv_bytes_saved_ratio"] = (1.0 - peak / wall) if wall else 0.0
        record["prefix"] = _prefix_workload(
            kw["arch"], kw["requests"], kw["batch"], kw["prefix_len"],
            kw["max_new"], kw["page_size"])
    return record


def check_schema(record):
    """Raise AssertionError when the emitted record drifts from the schema
    later analysis (and the acceptance trajectory) depends on.  Slotted-only
    archs (no paged decode path) legitimately omit 'paged' and carry an
    empty 'prefix' section."""
    for k in REQUIRED_KEYS:
        assert k in record, f"BENCH_serving.json schema drift: missing {k!r}"
    assert ("paged" in record) == bool(record["prefix"]), \
        "schema drift: paged section and prefix workload must co-occur"
    for section in ("paged", "slotted"):
        if record.get(section):
            for k in REQUIRED_SUMMARY_KEYS:
                assert k in record[section], \
                    f"schema drift: missing {section}.{k}"
    if record.get("prefix"):
        for k in REQUIRED_PREFIX_KEYS:
            assert k in record["prefix"], f"schema drift: missing prefix.{k}"


def run(**overrides):
    """Harness hook: [(name, us_per_call, derived), ...]."""
    kw = {**DEFAULTS, **overrides}
    r = _bench(**kw)
    s = r["slotted"]
    p = r.get("paged", s)
    px = r.get("prefix") or {}
    return [
        ("serving_tokens_per_sec", 0.0, p["tokens_per_sec"]),
        ("serving_tokens_per_sec_slotted", 0.0, s["tokens_per_sec"]),
        ("serving_ttft_p50", p["ttft_p50_s"] * 1e6, p["ttft_p50_s"]),
        ("serving_ttft_p99", p["ttft_p99_s"] * 1e6, p["ttft_p99_s"]),
        ("serving_itl_p50", p["itl_p50_s"] * 1e6, p["itl_p50_s"]),
        ("serving_itl_p99", p["itl_p99_s"] * 1e6, p["itl_p99_s"]),
        ("serving_kv_bytes_peak_paged", 0.0, p["kv_bytes_peak"]),
        ("serving_kv_bytes_peak_slotted", 0.0, s["kv_bytes_peak"]),
        ("serving_kv_bytes_saved_ratio", 0.0, r["kv_bytes_saved_ratio"]),
        ("serving_prefix_hit_rate", 0.0,
         px.get("hit", {}).get("prefix_hit_rate", 0.0)),
        ("serving_prefill_tokens_saved_ratio", 0.0,
         px.get("prefill_tokens_saved_ratio", 0.0)),
        ("serving_prefill_compile_count", 0.0, p["compile_count"]),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=DEFAULTS["arch"])
    ap.add_argument("--requests", type=int, default=DEFAULTS["requests"])
    ap.add_argument("--batch", type=int, default=DEFAULTS["batch"])
    ap.add_argument("--prompt-len", type=int, default=DEFAULTS["prompt_len"])
    ap.add_argument("--max-new", type=int, default=DEFAULTS["max_new"])
    ap.add_argument("--page-size", type=int, default=DEFAULTS["page_size"])
    ap.add_argument("--prefix-len", type=int, default=DEFAULTS["prefix_len"],
                    help="shared system-prompt length of the prefix-cache "
                         "workload section")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run + schema assertion (CI gate); "
                         "does not overwrite BENCH_serving.json")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_serving.json"))
    args = ap.parse_args()
    kw = dict(arch=args.arch, requests=args.requests, batch=args.batch,
              prompt_len=args.prompt_len, max_new=args.max_new,
              page_size=args.page_size, prefix_len=args.prefix_len)
    if args.smoke:
        kw.update(requests=6, batch=2, prompt_len=8, max_new=4,
                  page_size=4, prefix_len=16)
    r = _bench(**kw)
    record = {
        "arch": kw["arch"], "smoke": True, "requests": kw["requests"],
        "batch_slots": kw["batch"], "prompt_len": kw["prompt_len"],
        "max_new": kw["max_new"], "page_size": kw["page_size"], **r,
    }
    check_schema(record)
    if args.smoke:
        print("smoke OK: schema intact; prefix_hit_rate="
              f"{(record['prefix'] or {}).get('hit', {}).get('prefix_hit_rate', 0.0):.2f}")
        return
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
