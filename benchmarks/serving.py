"""Serving-engine benchmark: tokens/sec, TTFT, p50/p99 inter-token latency,
and paged-vs-slotted KV-cache memory.

    PYTHONPATH=src python benchmarks/serving.py [--arch qwen2.5-14b] \
        [--requests 16] [--batch 4] [--out BENCH_serving.json]

Protocol: for each KV layout (paged, slotted) one warm-up pass populates
the jit caches (prefill per prompt length + the single batched-decode
executable), then the measured pass serves a fresh queue of ragged-length
requests through the continuous-batching engine.  Results land in
``BENCH_serving.json`` so later PRs have a perf trajectory to beat — the
paged section's ``kv_bytes_peak`` vs ``kv_bytes_slotted`` is the memory
win, its ``tokens_per_sec`` guards against paged-kernel regressions.  The
``run()`` hook returns harness-style ``(name, us_per_call, derived)`` rows.

Note on latency semantics: since the ITL-under-preemption fix, inter-token
latency excludes preemption gaps (eviction -> resume time shows up in the
request's completion time, not as one giant ITL sample).
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

DEFAULTS = dict(arch="qwen2.5-14b", requests=16, batch=4, prompt_len=16,
                max_new=12, page_size=8)


def _serve_once(arch, requests, batch, prompt_len, max_new, kv_layout,
                page_size):
    import numpy as np
    from repro.configs import ServeConfig, get_config
    from repro.serving import ServingEngine

    cfg = get_config(arch, smoke=True)
    scfg = ServeConfig(max_batch=batch, max_queue=max(requests, 8),
                       max_seq_len=prompt_len + max_new,
                       max_new_tokens=max_new, prefill_chunk=2,
                       decode_steps=4, kv_layout=kv_layout,
                       page_size=page_size)
    engine = ServingEngine(cfg, scfg, seed=0)
    rng = np.random.default_rng(0)
    lengths = rng.integers(max(prompt_len // 2, 1), prompt_len + 1,
                           size=requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)) for l in lengths]
    # warm-up: compile prefill for every prompt length + the decode step
    engine.generate(prompts, max_new)
    # measured pass on a fresh engine state (same compiled callables)
    engine.metrics.reset()
    engine.results.clear()
    out = engine.generate(prompts, max_new)
    assert len(out) == requests and all(len(t) == max_new for t in out)
    return engine.paged, engine.metrics.summary()


def _bench(**kw):
    """{'paged': summary, 'slotted': summary, 'kv_bytes_saved_ratio': x}.

    Archs without a paged decode path (recurrent / MLA / windowed) bench
    the slotted layout only — no 'paged' section, ratio 0."""
    from repro.configs import get_config
    from repro.models import registry

    paged_ok = registry.build(
        get_config(kw["arch"], smoke=True)).paged_decode_fn is not None
    record = {}
    for layout in (("paged", "slotted") if paged_ok else ("slotted",)):
        is_paged, s = _serve_once(kw["arch"], kw["requests"], kw["batch"],
                                  kw["prompt_len"], kw["max_new"],
                                  layout, kw["page_size"])
        assert is_paged == (layout == "paged")
        record[layout] = s
    record["kv_bytes_saved_ratio"] = 0.0
    if paged_ok:
        peak = record["paged"]["kv_bytes_peak"]
        wall = record["paged"]["kv_bytes_slotted"]
        record["kv_bytes_saved_ratio"] = (1.0 - peak / wall) if wall else 0.0
    return record


def run(**overrides):
    """Harness hook: [(name, us_per_call, derived), ...]."""
    kw = {**DEFAULTS, **overrides}
    r = _bench(**kw)
    s = r["slotted"]
    p = r.get("paged", s)
    return [
        ("serving_tokens_per_sec", 0.0, p["tokens_per_sec"]),
        ("serving_tokens_per_sec_slotted", 0.0, s["tokens_per_sec"]),
        ("serving_ttft_p50", p["ttft_p50_s"] * 1e6, p["ttft_p50_s"]),
        ("serving_ttft_p99", p["ttft_p99_s"] * 1e6, p["ttft_p99_s"]),
        ("serving_itl_p50", p["itl_p50_s"] * 1e6, p["itl_p50_s"]),
        ("serving_itl_p99", p["itl_p99_s"] * 1e6, p["itl_p99_s"]),
        ("serving_kv_bytes_peak_paged", 0.0, p["kv_bytes_peak"]),
        ("serving_kv_bytes_slotted", 0.0, p["kv_bytes_slotted"]),
        ("serving_kv_bytes_saved_ratio", 0.0, r["kv_bytes_saved_ratio"]),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=DEFAULTS["arch"])
    ap.add_argument("--requests", type=int, default=DEFAULTS["requests"])
    ap.add_argument("--batch", type=int, default=DEFAULTS["batch"])
    ap.add_argument("--prompt-len", type=int, default=DEFAULTS["prompt_len"])
    ap.add_argument("--max-new", type=int, default=DEFAULTS["max_new"])
    ap.add_argument("--page-size", type=int, default=DEFAULTS["page_size"])
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_serving.json"))
    args = ap.parse_args()
    r = _bench(arch=args.arch, requests=args.requests, batch=args.batch,
               prompt_len=args.prompt_len, max_new=args.max_new,
               page_size=args.page_size)
    record = {
        "arch": args.arch, "smoke": True, "requests": args.requests,
        "batch_slots": args.batch, "prompt_len": args.prompt_len,
        "max_new": args.max_new, "page_size": args.page_size, **r,
    }
    Path(args.out).write_text(json.dumps(record, indent=2) + "\n")
    print(json.dumps(record, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
