# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper figure/table.

  fig456_ratios    — Figs. 4-6: compute/params/ratio relative to AlexNet
  fig7_equivalence — Fig. 7: distributed == sequential loss curves
  fig8_scaling     — Fig. 8: strong-scaling speedup (paper's §IV-A model)
  overhead         — §IV-B: runtime-injection overhead (~12% in the paper)
  roofline_table   — EXPERIMENTS.md §Roofline from the dry-run artifacts

Each module's ``run()`` returns [(name, us_per_call, derived), ...]; the
harness prints the combined CSV.
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (fig456_ratios, fig7_equivalence, fig8_scaling,
                            overhead, roofline_table, serving)
    modules = [fig456_ratios, fig8_scaling, overhead, fig7_equivalence,
               roofline_table, serving]
    rows = []
    failed = []
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        print(f"\n===== {name} =====", flush=True)
        try:
            rows.extend(mod.run() or [])
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
    print("\n===== CSV =====")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived:.6g}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
