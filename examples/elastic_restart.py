"""Fault tolerance demo: train on 4 DP replicas, kill a replica mid-run
(simulated fault), resume on 2 replicas from the last checkpoint — the
ULFM-style "continued execution" the paper targets (§II-B), enabled by the
DP replication argument of §III-B.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.failures import FaultInjector, run_with_recovery
from repro.configs import get_config
from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.transparent import TransparentTrainer
from repro.models import registry

CKPT = "/tmp/matexjax_elastic"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_config("stablelm-1.6b", smoke=True)
    bundle = registry.build(cfg)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                                      jnp.int32)} for _ in range(40)]

    def make_trainer(attempt):
        # first attempt: 4 DP replicas; after the fault: shrink to 2
        dp = 4 if attempt == 0 else 2
        print(f"[supervisor] building mesh with data={dp} "
              f"(attempt {attempt})")
        run = RunConfig(
            model=cfg, shape=ShapeConfig("e", "train", 16, 8),
            mesh=MeshConfig(shape=(dp, 2), axis_names=("data", "model"),
                            allreduce="layerwise"),
            optimizer=OptimizerConfig(name="adam", lr=1e-2))
        return TransparentTrainer(run, bundle.loss_fn, bundle.specs)

    state, hist = run_with_recovery(
        make_trainer=make_trainer,
        data_iter_factory=lambda start: iter(batches[start:]),
        ckpt_dir=CKPT, total_steps=30, ckpt_every=10,
        injector=FaultInjector(fail_at_steps=(17,)))

    print(f"\nrestarts: {hist['restarts']}  "
          f"resumed at steps: {hist['resume_steps']}")
    losses = hist["losses"]
    print("loss curve (around the fault at step 17):")
    for s, l in losses:
        mark = "  <- resumed here" if s in (11,) else ""
        print(f"  step {s:3d}  loss {l:.4f}{mark}")
    print("training survived the replica loss and finished on the "
          "shrunk mesh.")


if __name__ == "__main__":
    main()
