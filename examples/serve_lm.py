"""Serving example: the continuous-batching engine via the serve CLI
(admission queue -> per-slot KV insertion -> fixed-shape batched decode ->
streamed greedy generation; see src/repro/serving/).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def main():
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "qwen2.5-14b", "--smoke",
           "--requests", "6", "--batch", "3",
           "--prompt-len", "12", "--max-new", "8"]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    raise SystemExit(subprocess.run(cmd, env=env).returncode)


if __name__ == "__main__":
    main()
