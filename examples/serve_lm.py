"""Serving example: the continuous-batching engine through ``repro.api``
(admission queue -> per-slot KV insertion -> fixed-shape batched decode ->
streamed greedy generation; see src/repro/serving/).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro import api


def main():
    session = api.load("qwen2.5-14b", smoke=True, require=("serve",))
    rng = np.random.default_rng(0)
    vocab = session.model.vocab_size
    prompts = [list(rng.integers(0, vocab, (int(n),)))
               for n in rng.integers(6, 13, size=6)]

    outs = session.serve(
        prompts, max_new=8, max_batch=3,
        stream=lambda rid, tok, done: print(
            f"  req {rid} -> {tok}{'  [done]' if done else ''}", flush=True))
    s = session.engine.metrics.summary()
    print(f"served {s['completed']}/{len(prompts)} requests, "
          f"{s['tokens_out']} tokens ({s['tokens_per_sec']:.1f} tok/s)")
    for i, toks in enumerate(outs):
        print(f"  req {i}: {toks}")


if __name__ == "__main__":
    main()
