"""Quickstart: the paper's Fig. 3 experience, one Session for everything.

The "user script" below is purely sequential — pick a model, train it, ask
it for tokens.  ``repro.api`` is the runtime: it injects broadcast init,
gradient all-reduce, sharded data ingestion (training) and continuous
batching + KV-cache management (generation).  The ``mesh="4x2"`` string is
the *entire* distribution configuration: delete it and the identical
script runs on one device; grow it and the same script runs on a pod.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro import api


def main():
    # ----- user code (sequential, no distribution constructs) --------------
    session = api.load("stablelm-1.6b", smoke=True, mesh="4x2")
    print(session)

    result = session.train(steps=30, seq_len=32, global_batch=16,
                           log_every=5)
    print(f"trained {result.step} steps: loss {result.losses[0]:.4f} -> "
          f"{result.loss:.4f}")

    # one-shot generation from the trained weights, same Session
    tokens = session.generate([3, 1, 4, 1, 5, 9, 2, 6], max_new=12)
    print(f"generated: {tokens}")

    # a closed batch through the continuous-batching engine
    outs = session.serve([[1, 2, 3], [4, 5, 6, 7], [8, 9]], max_new=6)
    for i, toks in enumerate(outs):
        print(f"  req {i}: {toks}")
    print("done — trained data-parallel and served continuous-batch; "
          "the script stayed serial.")


if __name__ == "__main__":
    main()
