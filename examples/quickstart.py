"""Quickstart: the paper's Fig. 3 experience in JAX.

The "user script" below is purely sequential — it loads data, picks a model
and an optimizer, and calls step().  The MaTEx-JAX runtime makes it data-
parallel (broadcast init + layer-wise gradient all-reduce) without any
distribution code appearing here.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.transparent import TransparentTrainer
from repro.data.pipeline import make_input_pipeline
from repro.data.readers import synthetic_tokens
from repro.launch.mesh import build_mesh
from repro.models import registry


def main():
    # ----- user code (sequential, no distribution constructs) --------------
    cfg = get_config("stablelm-1.6b", smoke=True)     # any of the 10 archs
    bundle = registry.build(cfg)
    dataset = synthetic_tokens(cfg.vocab_size, seq_len=32, num_samples=512)
    optimizer = OptimizerConfig(name="adam", lr=1e-3)

    # ----- the runtime (what MaTEx patched into TensorFlow) ----------------
    mesh_cfg = MeshConfig(shape=(4, 2), axis_names=("data", "model"),
                          allreduce="layerwise")
    mesh = build_mesh(mesh_cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("qs", "train", 32, 16),
                    mesh=mesh_cfg, optimizer=optimizer)
    trainer = TransparentTrainer(run, bundle.loss_fn, bundle.specs, mesh=mesh)
    batches, pf = make_input_pipeline(dataset, global_batch=16, mesh=mesh,
                                      dp_axes=("data",))

    state = trainer.init(seed=0)
    print(f"devices={len(jax.devices())}  mesh={mesh_cfg.shape} "
          f"(data x model)  strategy={mesh_cfg.allreduce}")
    for i, batch in zip(range(30), batches):
        state, metrics = trainer.step(state, batch)
        if (i + 1) % 5 == 0:
            print(f"step {int(metrics['step']):3d}  "
                  f"loss {float(metrics['loss']):.4f}")
    pf.close()
    print("done — the model trained data-parallel; the script stayed serial.")


if __name__ == "__main__":
    main()
