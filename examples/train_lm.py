"""End-to-end training example: a ~100M-param LM through the full stack
(sharded data pipeline, transparent DP, checkpointing, straggler monitor)
on 8 placeholder devices — all through ``repro.api``.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

from repro import api


def main():
    steps = 200
    if "--steps" in sys.argv:
        steps = int(sys.argv[sys.argv.index("--steps") + 1])
    session = api.load("examples-lm-100m", mesh="4x2", allreduce="bucketed")
    result = session.train(steps=steps, seq_len=128, global_batch=16,
                           ckpt_dir="/tmp/matexjax_100m", ckpt_every=50,
                           log_every=10)
    s = result.straggler
    print(f"done: {result.step} steps, loss {result.loss:.4f}, "
          f"p50 {s.get('p50_s', 0.0)*1e3:.1f} ms/step, "
          f"total {result.elapsed_s:.1f}s")


if __name__ == "__main__":
    main()
