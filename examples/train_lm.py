"""End-to-end driver example: train a ~100M-param LM for a few hundred steps
through the full stack (sharded data pipeline, transparent DP, checkpointing,
straggler monitor) on 8 placeholder devices.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]

This wraps the production launcher (repro.launch.train) — the same driver
that runs full configs on a real pod.
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def main():
    steps = "200"
    if "--steps" in sys.argv:
        steps = sys.argv[sys.argv.index("--steps") + 1]
    # ~100M-param config: stablelm-1.6b geometry shrunk to 12 layers x 768
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "examples-lm-100m", "--steps", steps,
           "--seq-len", "128", "--global-batch", "16",
           "--dp", "4", "--tp", "2", "--allreduce", "bucketed",
           "--ckpt-dir", "/tmp/matexjax_100m", "--ckpt-every", "50",
           "--devices", "8"]
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in ("XLA_FLAGS",)})
    env["PYTHONPATH"] = str(ROOT / "src")
    raise SystemExit(subprocess.run(cmd, env=env).returncode)


if __name__ == "__main__":
    main()
