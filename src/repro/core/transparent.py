"""User-transparent distributed training — the paper's contribution (§III-D)
as a JAX runtime transform.

The user writes a *sequential* loss function (paper Fig. 3: the script has no
distribution code).  ``TransparentTrainer`` is the runtime: it injects

  * the Broadcast operator at initialization (§III-D.1, core/broadcast.py),
  * the gradient all-reduce after every batch   (§III-D.2, core/allreduce.py),
  * rank-sharded data ingestion                 (§III-F, repro.data),

exactly where MaTEx-TensorFlow patched the TensorFlow runtime.  Synchronous
data parallelism preserves numerical equivalence with the sequential run
(§III-E / Fig. 7) — tested in tests/test_equivalence.py.

Two placement modes:
  * ``replicated``  (paper-faithful): params replicated over DP axes inside a
    partial-manual shard_map; DP collectives are explicit and strategy-
    selectable; the "model" axis stays auto (GSPMD tensor parallelism).
  * ``fsdp``        (beyond-paper): pure pjit with 2-D parameter sharding
    (ZeRO-3 style); XLA emits all-gather/reduce-scatter pairs — the
    decomposition of the paper's allreduce.

Plus the ZeRO-1 ``reduce_scatter`` strategy: allreduce ≡ reduce-scatter +
all-gather with the optimizer update between the halves; optimizer moment
state is sharded over the DP axes as ``[dp, shard]`` arrays.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MeshConfig, RunConfig
from repro.core import allreduce as ar
from repro.core import broadcast as bc
from repro.core import compat
from repro.models import common
from repro.optim.optimizers import (Optimizer, clip_by_global_norm,
                                    global_norm, make_optimizer)

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# TrainState pytree
# ---------------------------------------------------------------------------

@dataclass
class TrainState:
    params: Any
    opt: Any
    err: Any          # error-feedback tree (compressed strategy) or None
    step: Any


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.err, s.step), None),
    lambda aux, ch: TrainState(*ch))


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def batch_pspec(leaf, dp_axes: Tuple[str, ...]) -> P:
    """Shard dim 0 (batch) over the DP axes, replicate the rest."""
    return P(tuple(dp_axes), *([None] * (leaf.ndim - 1)))


def _batch_specs_tree(batch_like, dp_axes):
    return jax.tree.map(lambda l: batch_pspec(l, dp_axes), batch_like)


def _flatten_to_vec(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])


def _unflatten_from_vec(vec, tree):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        out.append(vec[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def _linear_dp_rank(axes: Tuple[str, ...]):
    r = jnp.zeros((), jnp.int32)
    for a in axes:
        r = r * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return r


def _rank_scalar(axes: Tuple[str, ...], rank):
    """Linear DP rank: from the sharded rank input when provided (required
    under partial-auto on jax 0.4.x — axis_index won't partition), else
    derived from axis_index."""
    return rank[0] if rank is not None else _linear_dp_rank(axes)


def _scatter_mean_vec(vec, axes: Tuple[str, ...], pad_to: int, dp: int,
                      rank=None):
    """reduce-scatter(mean) of a flat fp32 vector -> local [pad_to/dp] shard."""
    v = jnp.pad(vec, (0, pad_to - vec.size))
    v = compat.psum_scatter_vec(v, axes, _rank_scalar(axes, rank),
                                pad_to // dp)
    return v / dp


def _gather_vec(shard, axes: Tuple[str, ...], pad_to: int, rank=None):
    return compat.all_gather_vec(shard, axes, _rank_scalar(axes, rank),
                                 pad_to)


def _local_param_shard(params, axes, pad_to: int, dp: int, rank=None):
    """This rank's slice of the flat parameter vector (no communication)."""
    vec = _flatten_to_vec(params)
    vec = jnp.pad(vec, (0, pad_to - vec.size))
    shard_size = pad_to // dp
    r = _rank_scalar(axes, rank)
    return jax.lax.dynamic_slice(vec, (r * shard_size,), (shard_size,))


def _num_microbatches(run_cfg: RunConfig, local_batch: int) -> int:
    mb = run_cfg.microbatch
    if mb <= 0 or mb >= local_batch:
        return 1
    assert local_batch % mb == 0, (local_batch, mb)
    return local_batch // mb


# ---------------------------------------------------------------------------
# The transparent primitive: drop-in value_and_grad with injected reduction
# ---------------------------------------------------------------------------

def value_and_grad(loss_fn, *, strategy: str = "layerwise",
                   axes: Tuple[str, ...] = ("data",), bucket_bytes: int = 32 << 20):
    """jax.value_and_grad drop-in that all-reduces gradients over the DP axes.

    For users writing custom loops inside a shard_map manual region — the
    same injection the paper performs in the TF runtime."""
    vg = jax.value_and_grad(loss_fn)

    def wrapped(params, *args, **kw):
        loss, grads = vg(params, *args, **kw)
        grads, _ = ar.reduce_gradients(grads, strategy, axes, bucket_bytes)
        return (jax.lax.pmean(loss, tuple(axes)) if axes else loss), grads

    return wrapped


class _RankedStepFn:
    """Compiled step closure that feeds the DP-rank input (rank-as-data;
    see ``_dp_ranks``) while keeping the public ``(state, batch)`` call and
    ``lower(state, batch)`` dry-run surfaces unchanged."""

    def __init__(self, jitted, ranks, rank_sharding):
        self._jitted = jitted
        self._ranks = ranks
        self._rank_sharding = rank_sharding

    def __call__(self, state, batch):
        return self._jitted(state, batch, self._ranks)

    def lower(self, state_structs, batch_structs):
        rank_struct = jax.ShapeDtypeStruct(
            self._ranks.shape, self._ranks.dtype,
            sharding=self._rank_sharding)
        return self._jitted.lower(state_structs, batch_structs, rank_struct)


# ---------------------------------------------------------------------------
# TransparentTrainer
# ---------------------------------------------------------------------------

class TransparentTrainer:
    """Runtime that turns a sequential loss_fn into synchronous DP training.

    loss_fn(params, batch) -> scalar; param_specs: ParamSpec tree.
    """

    @classmethod
    def from_bundle(cls, run_cfg: RunConfig, bundle, *, mesh=None,
                    optimizer: Optional[Optimizer] = None):
        """Session-owned construction (repro.api): a trainer straight from a
        registry ``ModelBundle`` — the bundle's ``TrainStepContract`` loss
        and ParamSpec tree, no hand-wiring of either at call sites."""
        return cls(run_cfg, bundle.loss_fn, bundle.specs, mesh=mesh,
                   optimizer=optimizer)

    def __init__(self, run_cfg: RunConfig, loss_fn: Callable, param_specs,
                 mesh=None, optimizer: Optional[Optimizer] = None):
        from repro.launch.mesh import build_mesh
        run_cfg.validate()
        self.run_cfg = run_cfg
        self.mesh_cfg = run_cfg.mesh
        self.mesh = mesh if mesh is not None else build_mesh(run_cfg.mesh)
        self.loss_fn = loss_fn
        self.param_specs = param_specs
        self.opt = optimizer or make_optimizer(run_cfg.optimizer)
        self.rules = common.rules_for(self.mesh_cfg, run_cfg.model)
        self.dp_axes = tuple(a for a in self.mesh_cfg.axis_names
                             if a in ("pod", "data"))
        self.dp = int(np.prod([s for s, a in zip(self.mesh_cfg.shape,
                                                 self.mesh_cfg.axis_names)
                               if a in ("pod", "data")])) or 1
        msize = int(np.prod([s for s, a in zip(self.mesh_cfg.shape,
                                               self.mesh_cfg.axis_names)
                             if a == "model"])) or 1
        # The paper-faithful manual region keeps the "model" axis auto
        # (GSPMD tensor parallelism).  Old jax cannot lower such partial-
        # auto regions (core.compat): go *fully* manual when the mesh is
        # pure-DP (model extent 1 — the paper's actual setting), otherwise
        # fall back to the GSPMD auto lowering (numerically equivalent;
        # the allreduce decomposition is then XLA's choice, not ours).
        if self.mesh_cfg.dp_mode == "replicated" and self.dp_axes:
            if compat.partial_auto_ok():
                self._manual_axes = set(self.dp_axes)
            elif msize == 1:
                self._manual_axes = set(self.mesh_cfg.axis_names)
            else:
                self._manual_axes = None          # auto fallback
        else:
            self._manual_axes = None
        self._zero1 = (self.mesh_cfg.allreduce == "reduce_scatter"
                       and self._manual_axes is not None)
        n_params = sum(int(np.prod(s.shape))
                       for s in common.spec_leaves(param_specs))
        self._n_params = n_params
        self._padded = -(-n_params // self.dp) * self.dp
        if self._zero1 and self._padded >= 2 ** 31:
            raise ValueError(
                f"zero1 flat-shard state ({n_params/1e9:.1f}B params) exceeds "
                "int32 dynamic-slice indexing — and replicated fp32 masters "
                "cannot fit HBM at this scale anyway; use dp_mode='fsdp'")
        self._step_cache: Dict[Any, Callable] = {}

    # -- structure builders ---------------------------------------------------

    def _opt_struct(self):
        """abstract opt-state structure (global shapes)."""
        pstructs = common.param_shape_structs(self.param_specs)
        if self._zero1:
            shard = self._padded // self.dp
            vec = jax.ShapeDtypeStruct((self.dp, shard), jnp.float32)
            return jax.eval_shape(self.opt.init,
                                  {"flat": jax.ShapeDtypeStruct((self.dp, shard),
                                                                jnp.float32)})
        return jax.eval_shape(self.opt.init, pstructs)

    def _opt_manual_specs(self):
        """shard_map in/out specs for the optimizer state."""
        struct = self._opt_struct()
        if self._zero1:
            dp_tuple = tuple(self.dp_axes)
            return jax.tree.map(
                lambda l: P(dp_tuple, None) if l.ndim == 2 else P(), struct)
        return jax.tree.map(lambda _: P(), struct)

    def _dp_ranks(self):
        """[dp] int32 linear ranks; sharded over the DP axes each replica's
        manual-region slice is its own rank — rank identity as data (see
        core.compat: axis_index can't lower under partial-auto on old jax)."""
        return jnp.arange(self.dp, dtype=jnp.int32)

    def param_shardings(self):
        return common.logical_to_mesh(self.param_specs, self.mesh, self.rules)

    def _param_manual_specs(self):
        return common.manual_axis_specs(self.param_specs, self.rules,
                                        self.dp_axes)

    def _ns(self, spec: P):
        return jax.sharding.NamedSharding(self.mesh, spec)

    def state_shardings(self):
        ps = self.param_shardings()
        rep = self._ns(P())
        if self._zero1:
            opt_sh = jax.tree.map(
                lambda l: self._ns(P(tuple(self.dp_axes), None))
                if l.ndim == 2 else rep, self._opt_struct())
        else:
            # optimizer moments mirror parameter shardings (matched by shape)
            pshapes = {}
            for l, s in zip(common.spec_leaves(self.param_specs),
                            jax.tree.leaves(ps)):
                pshapes.setdefault(tuple(l.shape), s)
            opt_sh = jax.tree.map(
                lambda l: pshapes.get(tuple(l.shape), rep), self._opt_struct())
        err_sh = (jax.tree.map(lambda s: s, ps)
                  if self.mesh_cfg.allreduce == "compressed" else None)
        return TrainState(params=ps, opt=opt_sh, err=err_sh, step=rep)

    def state_structs(self):
        """ShapeDtypeStructs (with shardings) for the dry-run."""
        pstructs = common.param_shape_structs(self.param_specs)
        err = (jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pstructs)
            if self.mesh_cfg.allreduce == "compressed" else None)
        structs = TrainState(params=pstructs, opt=self._opt_struct(), err=err,
                             step=jax.ShapeDtypeStruct((), jnp.int32))
        return jax.tree.map(
            lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype, sharding=sh),
            structs, self.state_shardings())

    # -- init ------------------------------------------------------------------

    def init(self, seed: int = 0):
        """Materialize a broadcast-consistent TrainState on the mesh."""
        mesh_cfg = self.mesh_cfg

        def _base_state(key):
            params = common.init_params(self.param_specs, key)
            err = (ar.init_error_tree(params)
                   if mesh_cfg.allreduce == "compressed" else None)
            return params, err

        if self._manual_axes is not None:
            pspecs = self._param_manual_specs()
            opt_specs = self._opt_manual_specs()
            err_specs = (jax.tree.map(lambda s: s, pspecs)
                         if mesh_cfg.allreduce == "compressed" else None)

            def _init_inner(key, rank):
                params, err = _base_state(key)
                # paper §III-D.1: rank-0 broadcast guarantees identical replicas
                params = bc.broadcast_masked(params, self.dp_axes,
                                             rank[0] == 0)
                if self._zero1:
                    shard = _local_param_shard(params, self.dp_axes,
                                               self._padded, self.dp,
                                               rank=rank)
                    opt = self.opt.init({"flat": shard[None, :]})
                else:
                    opt = self.opt.init(params)
                return TrainState(params=params, opt=opt, err=err,
                                  step=jnp.zeros((), jnp.int32))

            smapped = compat.shard_map(
                _init_inner, mesh=self.mesh,
                in_specs=(P(), P(tuple(self.dp_axes))),
                out_specs=TrainState(params=pspecs, opt=opt_specs,
                                     err=err_specs, step=P()),
                check_vma=False, axis_names=self._manual_axes)
            fn = jax.jit(smapped, out_shardings=self.state_shardings())
            return fn(jax.random.PRNGKey(seed), self._dp_ranks())
        else:
            def _init_auto(key):
                params, err = _base_state(key)
                return TrainState(params=params, opt=self.opt.init(params),
                                  err=err, step=jnp.zeros((), jnp.int32))
            fn = jax.jit(_init_auto, out_shardings=self.state_shardings())
            return fn(jax.random.PRNGKey(seed))

    # -- the transparent step ----------------------------------------------------

    def _grads_of(self, params, batch):
        loss, g = jax.value_and_grad(self.loss_fn)(params, batch)
        return loss, jax.tree.map(lambda x: x.astype(jnp.float32), g)

    def _accumulate(self, state, batch):
        local_b = jax.tree.leaves(batch)[0].shape[0]
        n_micro = _num_microbatches(self.run_cfg, local_b)
        if n_micro == 1:
            return self._grads_of(state.params, batch)
        mb = local_b // n_micro
        stacked = jax.tree.map(
            lambda x: x.reshape((n_micro, mb) + x.shape[1:]), batch)

        def acc_body(carry, micro):
            acc, loss_acc = carry
            loss, g = self._grads_of(state.params, micro)
            return (jax.tree.map(jnp.add, acc, g), loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state.params)
        (grads, loss), _ = jax.lax.scan(
            acc_body, (zeros, jnp.zeros((), jnp.float32)), stacked)
        return loss / n_micro, jax.tree.map(lambda g: g / n_micro, grads)

    def _local_step(self, state: TrainState, batch, rank=None):
        """Single-replica semantics + injected collectives (manual region)."""
        run_cfg, mesh_cfg = self.run_cfg, self.mesh_cfg
        loss, grads = self._accumulate(state, batch)
        new_err = state.err

        if self._zero1:
            # ZeRO-1: RS(mean) + sharded optimizer + AG (beyond-paper)
            vec = _flatten_to_vec(grads)
            gshard = _scatter_mean_vec(vec, self.dp_axes, self._padded,
                                       self.dp, rank=rank)
            sq = jax.lax.psum(jnp.sum(jnp.square(gshard)), tuple(self.dp_axes))
            gn = jnp.sqrt(sq)
            if run_cfg.optimizer.grad_clip:
                gshard = gshard * jnp.minimum(
                    1.0, run_cfg.optimizer.grad_clip / jnp.maximum(gn, 1e-12))
            pshard = _local_param_shard(state.params, self.dp_axes,
                                        self._padded, self.dp, rank=rank)
            new_pshard, new_opt = self.opt.update(
                {"flat": gshard[None, :]}, state.opt, {"flat": pshard[None, :]})
            new_vec = _gather_vec(new_pshard["flat"][0], self.dp_axes,
                                  self._padded, rank=rank)
            new_params = _unflatten_from_vec(new_vec[:self._n_params],
                                             state.params)
        else:
            grads, new_err = ar.reduce_gradients(
                grads, mesh_cfg.allreduce, self.dp_axes,
                mesh_cfg.bucket_bytes, state.err)
            if run_cfg.optimizer.grad_clip:
                grads, gn = clip_by_global_norm(grads,
                                                run_cfg.optimizer.grad_clip)
            else:
                gn = global_norm(grads)
            new_params, new_opt = self.opt.update(grads, state.opt,
                                                  state.params)

        if self.dp_axes:
            loss = jax.lax.pmean(loss, tuple(self.dp_axes))
        new_state = TrainState(params=new_params, opt=new_opt, err=new_err,
                               step=state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gn,
                           "step": new_state.step}

    def _build_step(self, batch_like):
        mesh_cfg = self.mesh_cfg
        state_sh = self.state_shardings()
        batch_sh = jax.tree.map(
            lambda l: self._ns(batch_pspec(l, self.dp_axes)), batch_like)

        if self._manual_axes is not None:
            state_specs = TrainState(
                params=self._param_manual_specs(),
                opt=self._opt_manual_specs(),
                err=(jax.tree.map(lambda s: s, self._param_manual_specs())
                     if mesh_cfg.allreduce == "compressed" else None),
                step=P())
            bspecs = _batch_specs_tree(batch_like, self.dp_axes)
            metric_specs = {"loss": P(), "grad_norm": P(), "step": P()}
            dp_tuple = tuple(self.dp_axes)
            smapped = compat.shard_map(
                self._local_step, mesh=self.mesh,
                in_specs=(state_specs, bspecs, P(dp_tuple)),
                out_specs=(state_specs, metric_specs),
                check_vma=False, axis_names=self._manual_axes)
            jfn = jax.jit(smapped,
                          in_shardings=(state_sh, batch_sh,
                                        self._ns(P(dp_tuple))),
                          out_shardings=(state_sh, None), donate_argnums=(0,))
            fn = _RankedStepFn(jfn, self._dp_ranks(), self._ns(P(dp_tuple)))
        else:
            # fsdp / auto mode: XLA derives reduce-scatter/all-gather from the
            # 2-D parameter sharding (beyond-paper ZeRO-3)
            def auto_step(state, batch):
                with common.activation_batch_axes(self.dp_axes):
                    loss, grads = self._accumulate(state, batch)
                if self.run_cfg.optimizer.grad_clip:
                    grads, gn = clip_by_global_norm(
                        grads, self.run_cfg.optimizer.grad_clip)
                else:
                    gn = global_norm(grads)
                params, opt = self.opt.update(grads, state.opt, state.params)
                new_state = TrainState(params=params, opt=opt, err=state.err,
                                       step=state.step + 1)
                return new_state, {"loss": loss, "grad_norm": gn,
                                   "step": new_state.step}

            fn = jax.jit(auto_step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        return fn

    def step_fn(self, batch_like):
        """Compiled train step for batches shaped like ``batch_like``."""
        key = tuple(sorted(
            (jax.tree_util.keystr(k), tuple(v.shape), str(v.dtype))
            for k, v in jax.tree_util.tree_leaves_with_path(batch_like)))
        if key not in self._step_cache:
            self._step_cache[key] = self._build_step(batch_like)
        return self._step_cache[key]

    def step(self, state, batch):
        return self.step_fn(batch)(state, batch)

    # -- lowering hook for the dry-run -----------------------------------------

    def lower_step(self, batch_structs):
        """lower() the train step against ShapeDtypeStructs (no allocation)."""
        batch_structs = jax.tree.map(
            lambda st: jax.ShapeDtypeStruct(
                st.shape, st.dtype,
                sharding=self._ns(batch_pspec(st, self.dp_axes))),
            batch_structs)
        return self.step_fn(batch_structs).lower(self.state_structs(),
                                                 batch_structs)
