"""Version-tolerant jax API aliases (the shard_map analogue of the kernels'
TPUCompilerParams alias).

``jax.shard_map`` (new-style: ``check_vma`` / ``axis_names`` kwargs) only
exists on newer jax; on 0.4.x the same machine lives at
``jax.experimental.shard_map.shard_map`` with the older ``check_rep`` /
``auto`` spelling — and its SPMD partitioner cannot lower ``axis_index`` /
``psum_scatter`` / ``all_gather`` inside *partial-auto* regions (the
trainer's replicated mode: manual DP axes, auto model axis).  This module
presents the new signature on both and provides psum-based fallbacks for
the collectives old jax cannot partition.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# captured at import time: conftest may later alias jax.shard_map to the
# wrapper below, so a live hasattr() probe would recurse
_NATIVE_SHARD_MAP = getattr(jax, "shard_map", None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
              axis_names=None):
    """New-style ``jax.shard_map`` signature on any supported jax version.

    ``axis_names``: the *manual* mesh axes (None/empty = all manual);
    the complement stays auto (GSPMD partitions it, e.g. tensor-parallel
    "model").  Translated to the old ``check_rep`` / ``auto`` spelling on
    jax 0.4.x.
    """
    if _NATIVE_SHARD_MAP is not None:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _NATIVE_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma,
                                 **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), auto=auto)


def partial_auto_ok() -> bool:
    """True when partial-auto shard_map regions fully work (new jax).

    On jax 0.4.x the SPMD partitioner cannot handle partial-auto regions
    containing ``axis_index`` (UNIMPLEMENTED: PartitionId), ``psum_scatter``
    / ``all_gather`` (fatal IsManualSubgroup check), or — critically —
    ``lax.scan`` over auto-axis-sharded operands (the model's layer stack):
    all of these abort or error.  Callers must then either go fully manual
    (possible when every mesh axis is a DP axis, i.e. no tensor
    parallelism) or fall back to the pure-GSPMD auto lowering."""
    return _NATIVE_SHARD_MAP is not None


# backwards-compatible alias (collectives were the first discovered gap)
partial_auto_collectives_ok = partial_auto_ok


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` returns a dict on new jax but a
    one-element list of dicts on 0.4.x; normalize to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def psum_scatter_vec(vec, axes: Tuple[str, ...], rank, shard_size: int):
    """Composed tiled ``psum_scatter`` of a flat vector over ``axes``;
    ``rank`` is this replica's linear DP rank (a traced scalar).

    Old jax: emulated as full psum + local dynamic slice — numerically
    identical (each output element is the same cross-replica sum), the wire
    pattern just degrades from RS to AR.
    """
    if partial_auto_collectives_ok():
        for a in axes:                  # sequential scatter composes the sum
            vec = jax.lax.psum_scatter(vec, a, scatter_dimension=0,
                                       tiled=True)
        return vec
    vec = jax.lax.psum(vec, tuple(axes))
    return jax.lax.dynamic_slice(vec, (rank * shard_size,), (shard_size,))


def all_gather_vec(shard, axes: Tuple[str, ...], rank, total: int):
    """Composed tiled ``all_gather`` of per-rank flat shards over ``axes``
    (inverse of :func:`psum_scatter_vec`).

    Old jax: emulated as place-own-shard + psum (every other contribution
    is zero), again identical in value."""
    if partial_auto_collectives_ok():
        for a in reversed(axes):
            shard = jax.lax.all_gather(shard, a, axis=0, tiled=True)
        return shard
    full = jnp.zeros((total,), shard.dtype)
    full = jax.lax.dynamic_update_slice(full, shard,
                                        (rank * shard.shape[0],))
    return jax.lax.psum(full, tuple(axes))
