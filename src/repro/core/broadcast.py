"""Broadcast operator — the paper's §III-D.1.

MaTEx-TensorFlow guarantees every replica starts from *identical* variables
by broadcasting rank 0's initial model.  The TF scheduler is unordered, so
the paper adds explicit data dependencies to match broadcast buffers; under
JAX/SPMD the dataflow graph provides that ordering for free, and the
broadcast itself is expressed as a masked psum: only the replica at
coordinate 0 along each DP axis contributes, everyone receives the sum.

This is not redundant with same-seed initialization: it makes replica
consistency *unconditional* (e.g. non-deterministic per-host init, restored
checkpoints with host-local corruption, or elastic re-join of a fresh
replica — §checkpoint.elastic).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def _is_rank_zero(axes: Sequence[str]):
    flag = jnp.ones((), jnp.bool_)
    for a in axes:
        flag &= jax.lax.axis_index(a) == 0
    return flag


def broadcast_masked(tree, axes: Sequence[str], mask):
    """Masked-psum broadcast: the replica(s) where ``mask`` is True
    contribute, everyone receives the sum.  ``mask`` lets callers supply
    rank identity as *data* (e.g. a sharded arange) — required under
    partial-auto shard_map on jax 0.4.x, where ``axis_index`` cannot be
    SPMD-partitioned."""
    if not axes:
        return tree

    def one(x):
        contrib = jnp.where(mask, x.astype(jnp.float32), 0.0)
        total = jax.lax.psum(contrib, tuple(axes))
        return total.astype(x.dtype)

    return jax.tree.map(one, tree)


def broadcast_from_rank0(tree, axes: Sequence[str]):
    """Inside a shard_map manual region: replace every leaf with rank 0's."""
    if not axes:
        return tree
    return broadcast_masked(tree, axes, _is_rank_zero(axes))


def replicas_identical(tree, axes: Sequence[str]):
    """Consistency check: max |x - rank0(x)| over all leaves (0.0 == equal)."""
    if not axes:
        return jnp.zeros((), jnp.float32)
    ref = broadcast_from_rank0(tree, axes)
    diffs = jax.tree.map(
        lambda a, b: jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))),
        tree, ref)
    return jax.tree.reduce(jnp.maximum, diffs, jnp.zeros((), jnp.float32))
