"""Gradient reduction strategies — the JAX/TPU mapping of the paper's
MPI_Allreduce operator (§III-D.2).

All strategies are *mathematically identical* (mean over DP replicas); they
differ in collective granularity and schedule, which is what the paper's
"layer-wise ordered all-to-all reduction" is about:

  fused          one flat psum (minimum latency-overhead count)
  layerwise      one psum per parameter tensor, ordered back-to-front —
                 the paper's design; allows overlap with remaining backprop
  bucketed       layerwise coalesced into ~bucket_bytes buckets
  hierarchical   psum over intra-pod "data" axis, then inter-pod "pod" axis
                 (topology-aware; TPU ICI vs cross-pod DCI)
  compressed     bf16 wire format + fp32 error-feedback (beyond-paper)

The ZeRO-1 ``reduce_scatter`` strategy lives in transparent.py because it
fuses with the optimizer update (allreduce ≡ reduce-scatter + all-gather
with the update between the halves).

These run inside a shard_map manual region over ``axes``; gradients are
fp32 trees.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _pmean(x, axes):
    if not axes:
        return x
    return jax.lax.pmean(x, tuple(axes))


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

def fused_allreduce(grads, axes: Sequence[str]):
    """Single collective over one concatenated fp32 vector."""
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    flat = _pmean(flat, axes)
    out, off = [], 0
    for l, n in zip(leaves, sizes):
        out.append(flat[off:off + n].reshape(l.shape))
        off += n
    return jax.tree.unflatten(treedef, out)


def layerwise_allreduce(grads, axes: Sequence[str], reverse: bool = True):
    """One psum per tensor, emitted in reverse tree order (gradients become
    available back-to-front during backprop — the paper's ordered list)."""
    leaves, treedef = jax.tree.flatten(grads)
    order = range(len(leaves) - 1, -1, -1) if reverse else range(len(leaves))
    reduced = [None] * len(leaves)
    for i in order:
        reduced[i] = _pmean(leaves[i].astype(jnp.float32), axes)
    return jax.tree.unflatten(treedef, reduced)


def bucketed_allreduce(grads, axes: Sequence[str], bucket_bytes: int):
    """Coalesce tensors (in reverse order) into ~bucket_bytes fp32 buckets."""
    leaves, treedef = jax.tree.flatten(grads)
    idx = list(range(len(leaves) - 1, -1, -1))
    buckets, cur, cur_bytes = [], [], 0
    for i in idx:
        n = leaves[i].size * 4
        if cur and cur_bytes + n > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += n
    if cur:
        buckets.append(cur)
    reduced = [None] * len(leaves)
    for bucket in buckets:
        flat = jnp.concatenate(
            [leaves[i].astype(jnp.float32).reshape(-1) for i in bucket])
        flat = _pmean(flat, axes)
        off = 0
        for i in bucket:
            n = leaves[i].size
            reduced[i] = flat[off:off + n].reshape(leaves[i].shape)
            off += n
    return jax.tree.unflatten(treedef, reduced)


def hierarchical_allreduce(grads, axes: Sequence[str]):
    """Reduce over the fast intra-pod axis first, then across pods.

    On a ("pod","data") manual region this lowers to two collectives whose
    communicators match the physical topology — the MPI analogue is a
    node-local reduce followed by an inter-node allreduce."""
    inner = [a for a in axes if a != "pod"]
    outer = [a for a in axes if a == "pod"]
    out = grads
    if inner:
        out = jax.tree.map(lambda g: _pmean(g.astype(jnp.float32), inner), out)
    if outer:
        out = jax.tree.map(lambda g: _pmean(g, outer), out)
    return out


def compressed_allreduce(grads, err, axes: Sequence[str]):
    """bf16 wire format with fp32 error feedback (beyond-paper).

    err: fp32 tree of residuals from previous steps (same structure).
    Returns (reduced fp32 grads, new err tree)."""
    # XLA:CPU check-fails on bf16 all-reduce inside partial-manual regions;
    # on CPU we keep the bf16 *quantization* (the dominant error term) but
    # use an fp32 wire so tests/dry-runs compile.  Roofline corrects the
    # wire bytes by /2 for this strategy (see roofline/analysis.py).
    cpu = jax.default_backend() == "cpu"

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        wire = g32.astype(jnp.bfloat16)
        new_e = g32 - wire.astype(jnp.float32)
        if cpu:
            red = _pmean(wire.astype(jnp.float32), axes)
        else:
            red = _pmean(wire, axes).astype(jnp.float32)
        return red, new_e

    pairs = jax.tree.map(one, grads, err)
    red = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return red, new_err


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def reduce_gradients(grads, strategy: str, axes: Sequence[str],
                     bucket_bytes: int = 32 << 20, err=None):
    """Apply a reduction strategy; returns (grads, new_err_or_None)."""
    if not axes:
        return jax.tree.map(lambda g: g.astype(jnp.float32), grads), err
    if strategy == "fused":
        return fused_allreduce(grads, axes), err
    if strategy == "layerwise":
        return layerwise_allreduce(grads, axes), err
    if strategy == "bucketed":
        return bucketed_allreduce(grads, axes, bucket_bytes), err
    if strategy == "hierarchical":
        return hierarchical_allreduce(grads, axes), err
    if strategy == "compressed":
        assert err is not None, "compressed strategy needs an error-feedback tree"
        return compressed_allreduce(grads, err, axes)
    raise ValueError(f"unknown strategy {strategy!r}")


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
