"""Mesh construction.  ``make_production_mesh`` is the deliverable entry
point; everything is a function (importing this module never touches jax
device state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False):
    """Production mesh: 16x16 (one 256-chip v5e pod) or 2x16x16 (two pods).

    The dry-run launcher sets XLA_FLAGS=--xla_force_host_platform_device_count=512
    *before* any jax import so this can build on CPU."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def build_mesh(cfg: MeshConfig):
    """Mesh from an arbitrary MeshConfig (tests use small shapes)."""
    return jax.make_mesh(tuple(cfg.shape), tuple(cfg.axis_names))


def mesh_config_for(mesh) -> MeshConfig:
    return MeshConfig(shape=tuple(mesh.devices.shape),
                      axis_names=tuple(mesh.axis_names))


def dp_size(mesh_cfg: MeshConfig) -> int:
    n = 1
    for s, a in zip(mesh_cfg.shape, mesh_cfg.axis_names):
        if a in ("pod", "data"):
            n *= s
    return n


def model_size(mesh_cfg: MeshConfig) -> int:
    n = 1
    for s, a in zip(mesh_cfg.shape, mesh_cfg.axis_names):
        if a == "model":
            n *= s
    return n
