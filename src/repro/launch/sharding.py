"""Sharding derivation for serve-side state (KV caches / recurrent states)
and inputs.  Train-side sharding lives in core/transparent.py.

jit-boundary in/out_shardings require exact divisibility, so every rule is
divisibility-guarded: e.g. GQA caches with KV=8 heads on a 16-way model axis
shard ``head_dim`` instead (128 % 16 == 0) — attention then contracts a
model-sharded dim and GSPMD inserts the score psum.

Cache leaves are name-matched (the trees are ours, names are stable):
  k/v        [L, B, Lc, KV, hd]   batch->dp; KV->model, else hd->model
  ckv/krope  [L, B, Lc, R]        batch->dp; R->model when divisible
  s          [L, B, H, hd, hd]    batch->dp; heads->model (wkv state)
  h          [n, B, W]            batch->dp; lru width->model
  conv       [n, B, cw-1, W]      batch->dp; width->model
  x / cm     [L, B, D]            batch->dp
  enc        [B, T, D]            batch->dp
  pos/index  replicated
"""
from __future__ import annotations

from typing import Tuple

import jax

P = jax.sharding.PartitionSpec


def _dp(dp_axes: Tuple[str, ...], batch: int, dp_total: int):
    if not dp_axes or batch <= 1 or batch % max(dp_total, 1) != 0:
        return None
    return tuple(dp_axes)


def serve_state_pspecs(state_structs, *, dp_axes: Tuple[str, ...],
                       dp_total: int, model_size: int):
    """PartitionSpec tree matching a decode-state struct tree."""

    def _model(dim_size: int):
        return "model" if model_size > 1 and dim_size % model_size == 0 \
            else None

    def rule(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        nd = leaf.ndim
        if name in ("pos", "index") or nd <= 1:
            return P()
        # batch dim position: stacked trees put it at dim 1; "enc" at dim 0
        bdim = 0 if name == "enc" else 1
        batch = leaf.shape[bdim] if nd > bdim else 1
        dp = _dp(dp_axes, batch, dp_total)
        spec = [None] * nd
        if dp is not None:
            spec[bdim] = dp
        if name in ("k", "v") and nd == 5:
            spec[3] = _model(leaf.shape[3])
            if spec[3] is None:
                spec[4] = _model(leaf.shape[4])      # shard head_dim instead
        elif name in ("ckv", "krope") and nd == 4:
            spec[3] = _model(leaf.shape[3])
        elif name == "s" and nd == 5:
            spec[2] = _model(leaf.shape[2])
            if spec[2] is None:
                spec[3] = _model(leaf.shape[3])
        elif name == "h" and nd == 3:
            spec[2] = _model(leaf.shape[2])
        elif name == "conv" and nd == 4:
            spec[3] = _model(leaf.shape[3])
        elif name in ("x", "cm") and nd == 3:
            pass                                     # small activations
        elif name == "enc" and nd == 3:
            pass                                     # replicated on model
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, state_structs)


def serve_input_pspecs(input_structs, *, dp_axes: Tuple[str, ...],
                       dp_total: int):
    def rule(leaf):
        if leaf.ndim == 0:
            return P()
        batch = leaf.shape[0]
        dp = _dp(dp_axes, batch, dp_total)
        return P(dp, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(rule, input_structs)


def with_shardings(structs, pspecs, mesh):
    return jax.tree.map(
        lambda st, sp: jax.ShapeDtypeStruct(
            st.shape, st.dtype,
            sharding=jax.sharding.NamedSharding(mesh, sp)),
        structs, pspecs)
