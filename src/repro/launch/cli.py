"""Shared CLI plumbing for the launch drivers (the flag builder behind
``repro.api``).

Every driver used to hand-roll the same argparse soup: ``--arch/--smoke``,
``--mesh DxM`` parsing, and the XLA placeholder-device bootstrap that must
happen *before* the first jax import.  They now live here exactly once;
each driver adds its workload-specific flags and asks for a ``Session``.

Import discipline: this module must stay importable without touching jax
device state — ``bootstrap_devices`` only sets XLA_FLAGS, and Session
construction defers all device work to first use (see repro.api.session).
"""
from __future__ import annotations

import argparse
import os
from typing import Optional

from repro.api.session import parse_mesh  # the single --mesh parser


def add_session_flags(ap: argparse.ArgumentParser, *,
                      arch_default: str = "qwen2.5-14b",
                      mesh_help: Optional[str] = None):
    """The flags every Session-backed driver shares."""
    ap.add_argument("--arch", default=arch_default)
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-sized config variant")
    ap.add_argument("--mesh", default=None, type=_mesh_arg,
                    help=mesh_help or
                    "device mesh 'D', 'DxM' or 'PxDxM' (e.g. 2x2 = 2-way "
                    "data x 2-way model; default: single device)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N placeholder CPU devices (0 = mesh size "
                         "when --mesh is set and jax is not yet imported)")
    return ap


def _mesh_arg(spec: str):
    try:
        return parse_mesh(spec)
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None


def bootstrap_devices(args):
    """Ensure enough placeholder CPU devices exist for ``args.mesh``.

    Must run before the first jax import: jax locks the device count on
    first initialization (same bootstrap all drivers used to copy-paste).
    Appends to an existing XLA_FLAGS (e.g. a user's --xla_dump_to) unless
    it already pins a device count of its own.
    """
    n = args.devices or (args.mesh.num_devices if args.mesh else 0)
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def make_session(args, **load_kw):
    """Build the Session a driver runs against (import deferred past
    ``bootstrap_devices`` on purpose)."""
    bootstrap_devices(args)
    from repro import api
    return api.load(args.arch, smoke=args.smoke, mesh=args.mesh,
                    seed=args.seed, **load_kw)
