import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh and record memory / cost / collective analyses.

This is the one driver that does NOT construct a ``repro.api.Session``: it
never executes a step — it lowers the same building blocks a Session owns
(registry bundles, ``TransparentTrainer.from_bundle``, the serve decode
contracts) against 512 placeholder devices to predict production memory /
cost.  User-facing train/serve entrypoints live behind ``repro.api`` and
``launch/cli.py``; the ``--mesh`` flag here selects the *production* preset
(single: 16x16, multi: 2x16x16), not the free-form ``DxM`` spec.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder CPU devices.
Smoke tests and benchmarks do NOT import this module (they see 1 device).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every runnable cell
  python -m repro.launch.dryrun --list           # enumerate cells

One JSON per cell lands in results/dryrun/; existing files are skipped
(incremental).  Run cells in subprocesses via --all to isolate compile memory.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, get_shape
from repro.configs.base import (MeshConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ShapeConfig, MULTI_POD, SINGLE_POD)
from repro.launch import sharding as shrules
from repro.launch.mesh import build_mesh, dp_size, make_production_mesh, model_size
from repro.models import common, registry
from repro.roofline import hw
from repro.roofline.hlo_parse import analyze_module

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# train-cell variants: the paper-faithful baseline and the beyond-paper modes
TRAIN_MODES = {
    "paper": dict(dp_mode="replicated", allreduce="layerwise"),
    "zero1": dict(dp_mode="replicated", allreduce="reduce_scatter"),
    "fsdp": dict(dp_mode="fsdp", allreduce="layerwise"),
}
DEFAULT_TRAIN_MODES = ("paper", "fsdp")


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell (deliverable)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    bundle = registry.build(cfg)
    if shape.kind == "train":
        return bundle.train_input_specs(shape)
    if shape.kind == "prefill":
        return bundle.prefill_input_specs(shape)
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "state": bundle.decode_state_specs(shape)}


def runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False                   # pure full-attention: documented skip
    return True


def all_cells(train_modes=DEFAULT_TRAIN_MODES):
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            shape = get_shape(shape_name)
            if not runnable(cfg, shape):
                continue
            for mesh_name in ("single", "multi"):
                if shape.kind == "train":
                    for mode in train_modes:
                        yield (arch, shape_name, mesh_name, mode)
                else:
                    yield (arch, shape_name, mesh_name, "serve")


def _mesh_cfg(mesh_name: str, **overrides) -> MeshConfig:
    base = SINGLE_POD if mesh_name == "single" else MULTI_POD
    import dataclasses
    return dataclasses.replace(base, **overrides)


def _bf16_param_structs(bundle):
    def one(s):
        dt = jnp.dtype(s.dtype)
        if jnp.issubdtype(dt, jnp.floating):
            dt = jnp.dtype(jnp.bfloat16)
        return jax.ShapeDtypeStruct(s.shape, dt)
    return common.tree_map_specs(
        lambda s: one(s), bundle.specs)


def _serve_param_rules(cfg, mesh_cfg):
    """Model-axis TP; switch to 2-D (embed->data) when bf16 weights would not
    fit model-sharded (e.g. mixtral-8x22b: 262 GB bf16 / 16 > HBM)."""
    n_params = registry.count_params(cfg)
    per_chip = 2.0 * n_params / model_size(mesh_cfg)
    rules = common.rules_for(mesh_cfg, cfg)
    if per_chip > 0.6 * hw.HBM_BYTES:
        rules = dict(rules)
        rules["embed"] = "data"
    return rules


def lower_cell(arch: str, shape_name: str, mesh_name: str, mode: str,
               overrides: dict = None):
    """overrides (hillclimb knobs): microbatch:int, remat:str,
    allreduce:str, rules:{logical->mesh axis}."""
    ov = overrides or {}
    cfg = get_config(arch)
    if ov.get("remat"):
        cfg = cfg.replace(remat=ov["remat"])
    if ov.get("q_block"):
        cfg = cfg.replace(attn_q_block=int(ov["q_block"]))
    if ov.get("kv_block"):
        cfg = cfg.replace(attn_kv_block=int(ov["kv_block"]))
    if ov.get("attn_remat"):
        cfg = cfg.replace(attn_remat=True)
    shape = get_shape(shape_name)
    bundle = registry.build(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rules_override = tuple(sorted(ov.get("rules", {}).items()))

    if shape.kind == "train":
        from repro.core.transparent import TransparentTrainer
        kw = dict(TRAIN_MODES[mode])
        if ov.get("allreduce"):
            kw["allreduce"] = ov["allreduce"]
        mesh_cfg = _mesh_cfg(mesh_name, rules_override=rules_override, **kw)
        run = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                        optimizer=OptimizerConfig(name="adam"),
                        microbatch=int(ov.get("microbatch", 2)))
        trainer = TransparentTrainer.from_bundle(run, bundle, mesh=mesh)
        return trainer.lower_step(bundle.train_input_specs(shape)), mesh, cfg

    mesh_cfg = _mesh_cfg(mesh_name, rules_override=rules_override)
    dp_axes = mesh_cfg.dp_axes
    dp = dp_size(mesh_cfg)
    msize = model_size(mesh_cfg)
    rules = _serve_param_rules(cfg, mesh_cfg)
    pshard = common.logical_to_mesh(bundle.specs, mesh, rules)
    pstructs = jax.tree.map(
        lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype, sharding=sh),
        _bf16_param_structs(bundle), pshard)

    if shape.kind == "prefill":
        inputs = bundle.prefill_input_specs(shape)
        ispecs = shrules.serve_input_pspecs(inputs, dp_axes=dp_axes, dp_total=dp)
        istructs = shrules.with_shardings(inputs, ispecs, mesh)

        def _prefill(params, inp):
            with common.activation_batch_axes(dp_axes):
                return bundle.prefill_fn(params, **inp)
        fn = jax.jit(_prefill)
        return fn.lower(pstructs, istructs), mesh, cfg

    # decode
    state = bundle.decode_state_specs(shape)
    sspecs = shrules.serve_state_pspecs(state, dp_axes=dp_axes, dp_total=dp,
                                        model_size=msize)
    sstructs = shrules.with_shardings(state, sspecs, mesh)
    tok = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}
    tspecs = shrules.serve_input_pspecs(tok, dp_axes=dp_axes, dp_total=dp)
    tstructs = shrules.with_shardings(tok, tspecs, mesh)
    state_sh = jax.tree.map(
        lambda sp: jax.sharding.NamedSharding(mesh, sp), sspecs)

    def _decode(params, tokens, state):
        with common.activation_batch_axes(dp_axes):
            return bundle.decode_fn(params, tokens, state)
    fn = jax.jit(_decode, donate_argnums=(2,),
                 out_shardings=(None, state_sh))
    return fn.lower(pstructs, tstructs["tokens"], sstructs), mesh, cfg


def analyze_cell(arch: str, shape_name: str, mesh_name: str, mode: str,
                 save_hlo: bool = False, overrides: dict = None):
    t0 = time.time()
    lowered, mesh, cfg = lower_cell(arch, shape_name, mesh_name, mode,
                                    overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    from repro.core.compat import cost_analysis
    ma = compiled.memory_analysis()
    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    stats = analyze_module(hlo)
    shape = get_shape(shape_name)
    n_dev = int(np.prod(mesh.devices.shape))

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "mode": mode,
        "devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        },
        "xla_cost": {"flops": ca.get("flops", 0.0),
                     "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "hlo_stats": {
            "dot_flops": stats.dot_flops,
            "conv_flops": stats.conv_flops,
            "hbm_bytes": stats.hbm_bytes,
            "wire_bytes": stats.wire_bytes_total,
            "collectives": stats.collective_summary(),
            "while_trip_counts": stats.while_trip_counts[:50],
        },
        "model_flops_global": registry.model_flops(cfg, shape),
        "params_total": registry.count_params(cfg),
        "params_active": registry.count_params(cfg, active_only=True),
        "hlo_bytes_len": len(hlo),
        "overrides": overrides or {},
    }
    if save_hlo:
        import gzip
        with gzip.open(RESULTS_DIR /
                       f"{_cell_id(arch, shape_name, mesh_name, mode)}.hlo.gz",
                       "wt") as f:
            f.write(hlo)
    return rec


def _cell_id(arch, shape, mesh, mode, tag=""):
    base = f"{arch}__{shape}__{mesh}__{mode}"
    if tag:
        base += f"__{tag}"
    return base.replace("/", "_")


def run_one(arch, shape, mesh, mode, save_hlo=False, overrides=None, tag=""):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{_cell_id(arch, shape, mesh, mode, tag)}.json"
    try:
        rec = analyze_cell(arch, shape, mesh, mode, save_hlo, overrides)
        rec["ok"] = True
        if tag:
            rec["tag"] = tag
    except Exception as e:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "mode": mode,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    out.write_text(json.dumps(rec, indent=1))
    status = "OK " if rec.get("ok") else "ERR"
    print(f"[{status}] {out.name}  compile={rec.get('compile_s', '-')}s",
          flush=True)
    return rec.get("ok", False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default=None,
                    help="train: paper|zero1|fsdp; serve cells ignore this")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="with --all: isolate each cell in a subprocess")
    # hillclimb knobs (single-cell runs)
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--remat", default=None, choices=["none", "full", "dots"])
    ap.add_argument("--allreduce-override", default=None)
    ap.add_argument("--rules", default=None,
                    help="logical=mesh axis overrides, e.g. "
                         "'vocab_table=model,embed=data'")
    ap.add_argument("--q-block", type=int, default=None)
    ap.add_argument("--kv-block", type=int, default=None)
    ap.add_argument("--attn-remat", action="store_true")
    args = ap.parse_args()

    overrides = {}
    if args.microbatch is not None:
        overrides["microbatch"] = args.microbatch
    if args.remat:
        overrides["remat"] = args.remat
    if args.allreduce_override:
        overrides["allreduce"] = args.allreduce_override
    if args.q_block:
        overrides["q_block"] = args.q_block
    if args.kv_block:
        overrides["kv_block"] = args.kv_block
    if args.attn_remat:
        overrides["attn_remat"] = True
    if args.rules:
        overrides["rules"] = {
            k: (v if v not in ("None", "none", "") else None)
            for k, v in (kv.split("=") for kv in args.rules.split(","))}

    if args.list or args.all:
        cells = list(all_cells())
        if args.list:
            for c in cells:
                print("%s %s %s %s" % c)
            print(f"total: {len(cells)} lowering cells")
            return
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        n_ok = n_err = n_skip = 0
        for (arch, shape, mesh, mode) in cells:
            out = RESULTS_DIR / f"{_cell_id(arch, shape, mesh, mode)}.json"
            if out.exists() and not args.force:
                prev = json.loads(out.read_text())
                if prev.get("ok"):
                    n_skip += 1
                    continue
            if args.subprocess:
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", arch, "--shape", shape, "--mesh", mesh,
                     "--mode", mode, "--force"]
                    + (["--save-hlo"] if args.save_hlo else []),
                    env={**os.environ, "PYTHONPATH": str(Path(__file__).resolve().parents[2])})
                ok = r.returncode == 0 and json.loads(out.read_text()).get("ok", False)
            else:
                ok = run_one(arch, shape, mesh, mode, args.save_hlo)
            n_ok += int(ok)
            n_err += int(not ok)
        print(f"done: ok={n_ok} err={n_err} skipped={n_skip}")
        return

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    shape = get_shape(args.shape)
    mode = args.mode or ("paper" if shape.kind == "train" else "serve")
    ok = run_one(args.arch, args.shape, args.mesh, mode, args.save_hlo,
                 overrides=overrides or None, tag=args.tag)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
