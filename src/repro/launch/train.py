"""Training CLI: a thin adapter over ``repro.api`` (the one supported
entrypoint — the Session owns the trainer, data pipeline, checkpoints and
mesh lifecycle; this file only turns flags into a ``Session.train`` call).

    PYTHONPATH=src python -m repro.launch.train \
        --arch stablelm-1.6b --smoke --steps 100 --mesh 4x2 \
        --allreduce layerwise --ckpt-dir /tmp/ckpt

The user-visible script is sequential, per the paper's thesis: the mesh /
allreduce / dp-mode flags select the distribution, they never change the
training code path.  On the CPU container use --smoke (reduced configs); on
a real pod the same driver runs the full configs.
"""
from __future__ import annotations

import argparse

from repro.launch import cli


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    cli.add_session_flags(ap, arch_default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--dp-mode", default=None,
                    choices=["replicated", "fsdp"])
    ap.add_argument("--allreduce", default=None)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    session = cli.make_session(args, dp_mode=args.dp_mode,
                               allreduce=args.allreduce)
    from repro.configs.base import OptimizerConfig
    result = session.train(
        steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch,
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr),
        microbatch=args.microbatch, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume, log_every=10)
    s = result.straggler
    print(f"done: {result.step} steps, loss {result.loss:.4f}, "
          f"p50 {s.get('p50_s', 0.0)*1e3:.1f} ms/step, "
          f"total {result.elapsed_s:.1f}s")


if __name__ == "__main__":
    main()
