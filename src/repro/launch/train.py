"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch stablelm-1.6b --smoke --steps 100 --dp 4 --tp 2 \
        --allreduce layerwise --ckpt-dir /tmp/ckpt

Wires together every substrate layer: rank-sharded data (repro.data), the
transparent DP runtime (repro.core), optimizers, checkpoint/restart and the
straggler monitor.  On the CPU container use --smoke (reduced configs);
on a real pod the same driver runs the full configs.
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--dp", type=int, default=0, help="0 = all devices / tp")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp-mode", default="replicated",
                    choices=["replicated", "fsdp"])
    ap.add_argument("--allreduce", default="layerwise")
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N placeholder CPU devices (demo runs)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    from repro.checkpoint.checkpoint import latest_step, save_checkpoint
    from repro.checkpoint.elastic import restore_elastic
    from repro.checkpoint.failures import StragglerMonitor
    from repro.configs import get_config
    from repro.configs.base import (MeshConfig, OptimizerConfig, RunConfig,
                                    ShapeConfig)
    from repro.core.transparent import TransparentTrainer
    from repro.data.pipeline import make_input_pipeline
    from repro.data.readers import synthetic_tokens
    from repro.launch.mesh import build_mesh
    from repro.models import registry

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = registry.build(cfg)
    n_dev = len(jax.devices())
    dp = args.dp or max(n_dev // args.tp, 1)
    mesh_cfg = MeshConfig(shape=(dp, args.tp), axis_names=("data", "model"),
                          dp_mode=args.dp_mode, allreduce=args.allreduce)
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", "train", args.seq_len, args.global_batch),
        mesh=mesh_cfg,
        optimizer=OptimizerConfig(name=args.optimizer, lr=args.lr),
        microbatch=args.microbatch)
    mesh = build_mesh(mesh_cfg)
    trainer = TransparentTrainer(run, bundle.loss_fn, bundle.specs, mesh=mesh)

    ds = synthetic_tokens(cfg.vocab_size, args.seq_len,
                          num_samples=args.global_batch * 64,
                          rank=jax.process_index(),
                          world=max(jax.process_count(), 1))
    it, pf = make_input_pipeline(ds, args.global_batch, mesh, ("data",))

    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore_elastic(args.ckpt_dir, trainer)
        print(f"resumed from step {start}")
    else:
        state = trainer.init(0)
    monitor = StragglerMonitor()

    print(f"arch={cfg.name} devices={n_dev} mesh={mesh_cfg.shape} "
          f"dp_mode={args.dp_mode} allreduce={args.allreduce}")
    t_start = time.time()
    step = start
    for batch in it:
        t0 = time.time()
        state, m = trainer.step(state, batch)
        straggler = monitor.record(time.time() - t0)
        step = int(m["step"])
        if step % 10 == 0 or step == start + 1:
            print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}"
                  + ("  [straggler]" if straggler else ""), flush=True)
        if args.ckpt_dir and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, state, step, blocking=False)
        if step >= start + args.steps:
            break
    pf.close()
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, state, step, blocking=True)
    s = monitor.summary()
    print(f"done: {step} steps, p50 {s['p50_s']*1e3:.1f} ms/step, "
          f"total {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
