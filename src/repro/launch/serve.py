"""Serving CLI: a thin adapter over ``repro.api`` — flags in, a
``Session.serve`` call out.  The Session owns the continuous-batching
engine (``repro.serving``): admission queue, per-slot KV insertion /
eviction, fixed-shape batched decode, paged-vs-slotted KV layout chosen by
the bundle's declared capabilities.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2.5-14b --smoke --requests 8 --prompt-len 16 --max-new 12

Pass ``--mesh DxM`` (e.g. ``2x1``) to serve data-parallel over slots and
tensor-parallel within decode on a device mesh — selected by config, no
code changes, per the paper's transparency principle.  Prompt lengths are
jittered to exercise ragged continuous batching.
"""
from __future__ import annotations

import argparse
import json

from repro.launch import cli


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    cli.add_session_flags(ap)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (fixed batched-decode shape)")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="max prompt length (lengths jittered down to half)")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--policy", choices=("fcfs", "priority"), default="fcfs")
    ap.add_argument("--max-prefills-per-step", "--prefill-chunk", type=int,
                    default=2, dest="max_prefills_per_step",
                    help="request admissions per engine cycle "
                         "(--prefill-chunk is the deprecated spelling)")
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0,
                    help="split prefills longer than this into per-cycle "
                         "chunks interleaved with decode (0 = whole prompt)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prefix-cache page sharing (paged layout)")
    ap.add_argument("--no-prefill-bucket", action="store_true",
                    help="disable power-of-two prefill length bucketing "
                         "(compiles one prefill per distinct prompt length)")
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--kv-layout", choices=("auto", "paged", "slotted"),
                    default="auto",
                    help="KV-cache layout: page-granular (any family with "
                         "a KVLayout: full/swa/local k-v pages, MLA latent "
                         "pages) vs slot-granular preallocation")
    ap.add_argument("--kv-dtype", choices=("fp32", "int8"), default="fp32",
                    help="paged KV page storage dtype: int8 stores k/v "
                         "pages quantized with per-page-per-head scales "
                         "(~4x less KV HBM; dequant fused into the paged-"
                         "attention kernels; MLA latent pages stay fp)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page (paged layout; default 16, "
                         "auto-shrunk for short runs and to tile the "
                         "attention window of swa/local families)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="shared page pool size; 0 = worst case, less "
                         "oversubscribes (engine preempts on pressure)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every request "
                         "(0 = greedy argmax, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep the k highest logits before sampling "
                         "(0 disables the filter)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest prob mass "
                         ">= top_p (1.0 disables the filter)")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="max draft tokens per speculative verify "
                         "(paged layouts; see --no-spec)")
    ap.add_argument("--no-spec", action="store_true",
                    help="disable speculative decoding (n-gram drafting + "
                         "one-forward verification; output tokens are "
                         "identical either way — spec only changes speed)")
    ap.add_argument("--sync", action="store_true",
                    help="synchronous escape hatch: pipeline_depth=1 — "
                         "retire every cycle before planning the next "
                         "(default pipeline_depth=2 overlaps host planning "
                         "with the in-flight device step)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are emitted")
    ap.add_argument("--json", action="store_true",
                    help="emit the metrics summary as JSON")
    ap.add_argument("--trace", metavar="PATH", default="",
                    help="run the engine traced (repro.obs) and write a "
                         "Perfetto-loadable Chrome trace JSON here; also "
                         "prints the per-phase time breakdown (fencing "
                         "costs throughput — don't combine with measured "
                         "runs)")
    args = ap.parse_args()

    # require the serve capability at load time: a family the engine cannot
    # serve fails in one line here, not mid-run
    session = cli.make_session(args, require=("serve",))

    import numpy as np
    rng = np.random.default_rng(args.seed)
    lengths = rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1,
                           size=args.requests)
    vocab = session.model.vocab_size
    prompts = [list(rng.integers(0, vocab, (int(l),))) for l in lengths]

    stream = None
    if args.stream:
        def stream(rid, tok, done):
            print(f"  req {rid} -> {tok}{'  [done]' if done else ''}",
                  flush=True)

    # --seed doubles as the sampling seed: with --temperature > 0 every
    # request draws from the same per-request (seed, token index) keyed
    # PRNG, so a rerun with identical flags reproduces its tokens exactly
    sampling = None
    if args.temperature > 0 or args.top_k > 0 or args.top_p < 1.0:
        from repro.serving import SamplingParams
        sampling = SamplingParams(temperature=args.temperature,
                                  top_k=args.top_k, top_p=args.top_p,
                                  seed=args.seed)

    seq_cap = args.prompt_len + args.max_new
    # without --page-size the Session auto-sizes pages from the model's
    # KVLayout (shrinks for short runs, tiles swa/local windows); an
    # explicit --page-size that doesn't fit should fail validation
    page_kw = {} if args.page_size is None else \
        {"page_size": args.page_size}
    outs = session.serve(
        prompts, max_new=args.max_new, stream=stream,
        max_batch=args.batch, max_queue=args.max_queue,
        max_seq_len=seq_cap, policy=args.policy,
        max_prefills_per_step=args.max_prefills_per_step,
        prefill_chunk_tokens=args.prefill_chunk_tokens,
        enable_prefix_cache=not args.no_prefix_cache,
        prefill_bucket=not args.no_prefill_bucket,
        decode_steps=args.decode_steps,
        kv_layout=args.kv_layout, kv_dtype=args.kv_dtype,
        pipeline_depth=1 if args.sync else 2,
        num_pages=args.num_pages, trace=bool(args.trace),
        spec_tokens=args.spec_tokens, enable_spec=not args.no_spec,
        sampling=sampling, **page_kw)
    engine = session.engine
    s = engine.metrics.summary()
    if args.json:
        print(json.dumps(s, indent=2))
    else:
        print(f"served {s['completed']}/{args.requests} requests, "
              f"{s['tokens_out']} tokens in {s['elapsed_s']:.2f}s "
              f"({s['tokens_per_sec']:.1f} tok/s)")
        print(f"  ttft   p50 {s['ttft_p50_s']*1e3:8.1f} ms   "
              f"p99 {s['ttft_p99_s']*1e3:8.1f} ms")
        print(f"  itl    p50 {s['itl_p50_s']*1e3:8.1f} ms   "
              f"p99 {s['itl_p99_s']*1e3:8.1f} ms")
        print(f"  queue  max {s['queue_depth_max']}  "
              f"preemptions {s['preemptions']}  rejected {s['rejected']}")
        layout = "paged" if engine.paged else "slotted"
        print(f"  kv     {layout}/{args.kv_dtype}  "
              f"peak {s['kv_bytes_peak']/1e6:.2f} MB  "
              f"(slotted pool would pin {s['kv_bytes_slotted']/1e6:.2f} MB)")
        if engine.paged and engine.layout.quantized:
            pool = engine.pool
            print(f"  kvq    {pool.page_bytes} B/page quantized vs "
                  f"{pool.page_bytes_fp32} B/page fp32 "
                  f"({pool.page_bytes / pool.page_bytes_fp32:.2f}x — "
                  f"{pool.page_bytes_fp32 / pool.page_bytes:.1f}x more "
                  f"tokens in the same HBM)")
        print(f"  prefill  {s['prefill_tokens']} tokens run, "
              f"{s['prefill_tokens_saved']} served from prefix cache "
              f"(hit rate {s['prefix_hit_rate']:.2f}), "
              f"{s['compile_count']} compiles")
        if s["drafted_tokens"]:
            print(f"  spec     {s['drafted_tokens']} drafted, "
                  f"{s['accepted_tokens']} accepted "
                  f"(accept_rate {s['accept_rate']:.2f})")
        if s["step_time_s"] > 0:
            st = s["step_time_s"]
            print(f"  phases plan {s['plan_time_s']/st:6.1%}  "
                  f"prefill {s['prefill_time_s']/st:6.1%}  "
                  f"decode {s['decode_time_s']/st:6.1%}  "
                  f"other {s['other_time_s']/st:6.1%}  "
                  f"of {st:.2f}s engine wall "
                  f"(host_overhead_frac {s['host_overhead_frac']:.2f}; "
                  f"decode {s['decode_tokens_per_sec']:.1f} tok/s, "
                  f"prefill {s['prefill_tokens_per_sec']:.1f} tok/s)")
        for i, toks in enumerate(outs):
            print(f"  req {i}: {toks[:8]}{'...' if len(toks) > 8 else ''}")
    if args.trace:
        print(f"trace written to {session.save_trace(args.trace)} "
              "(load in ui.perfetto.dev)", flush=True)


if __name__ == "__main__":
    main()
