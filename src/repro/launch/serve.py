"""Batched serving driver: prefill + decode loop with request batching.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2.5-14b --smoke --requests 8 --prompt-len 16 --max-new 12

A deliberately small but real serving loop: a queue of requests is packed
into a fixed decode batch; prefill builds each sequence's cache; decode
steps run the whole batch; finished sequences are swapped out.  (Per-slot
cache insertion is the production path on TPU; the CPU demo re-prefills
the batch when it changes, which is equivalent for correctness.)
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = registry.build(cfg)
    if bundle.prefill_fn is None:
        raise SystemExit(f"{args.arch} has no serve path")
    params = bundle.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    prompts = [rng.integers(0, cfg.vocab_size, (args.prompt_len,))
               for _ in range(args.requests)]
    pending = list(range(args.requests))
    done = {}
    prefill = jax.jit(bundle.prefill_fn)
    decode = jax.jit(bundle.decode_fn)

    t0 = time.time()
    n_decode_steps = 0
    while pending:
        batch_ids = pending[:args.batch]
        pending = pending[len(batch_ids):]
        toks = jnp.asarray(np.stack([prompts[i] for i in batch_ids]),
                           jnp.int32)
        if cfg.family == "encdec":
            frames = jnp.zeros((len(batch_ids), cfg.encdec.encoder_seq_len,
                                cfg.d_model), jnp.float32)
            logits, state = prefill(params, frames, toks)
        elif cfg.family == "vlm":
            patches = jnp.zeros((len(batch_ids), cfg.vlm.num_image_tokens,
                                 cfg.d_model), jnp.float32)
            logits, state = prefill(params, toks, patches)
        else:
            logits, state = prefill(params, toks)
        outs = [[int(jnp.argmax(logits[j]))] for j in range(len(batch_ids))]
        for _ in range(args.max_new - 1):
            last = jnp.asarray([[o[-1]] for o in outs], jnp.int32)
            logits, state = decode(params, last, state)
            n_decode_steps += 1
            for j in range(len(batch_ids)):
                outs[j].append(int(jnp.argmax(logits[j])))
        for j, rid in enumerate(batch_ids):
            done[rid] = outs[j]
        print(f"completed batch {batch_ids} "
              f"({len(done)}/{args.requests})", flush=True)
    dt = time.time() - t0
    total_new = sum(len(v) for v in done.values())
    print(f"served {args.requests} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s on CPU)")
    for rid in sorted(done):
        print(f"  req {rid}: {done[rid][:8]}...")


if __name__ == "__main__":
    main()
