"""Serving CLI: thin driver over the continuous-batching engine
(``repro.serving``).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen2.5-14b --smoke --requests 8 --prompt-len 16 --max-new 12

Requests enter an admission queue and are prefilled into KV-cache *slots*
individually (per-slot insertion/eviction — no batch re-prefill); decode
runs over the fixed slot pool so XLA compiles the batched step exactly
once.  For the attention (lm) family KV memory is page-granular
(``--kv-layout``/``--page-size``): pages allocate lazily with sequence
length and free on eviction, so cache bytes track live tokens rather than
``batch x max_seq_len``.  Prompt lengths are jittered to exercise ragged
continuous batching.
Pass ``--mesh DxM`` (e.g. ``2x1``) to serve data-parallel over slots and
tensor-parallel within decode on a device mesh — selected by config, no
code changes, per the paper's transparency principle.
"""
from __future__ import annotations

import argparse
import json
import os


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (fixed batched-decode shape)")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="max prompt length (lengths jittered down to half)")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--policy", choices=("fcfs", "priority"), default="fcfs")
    ap.add_argument("--prefill-chunk", type=int, default=2)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--kv-layout", choices=("auto", "paged", "slotted"),
                    default="auto",
                    help="KV-cache layout: page-granular (attention lm "
                         "family) vs slot-granular preallocation")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="shared page pool size; 0 = worst case, less "
                         "oversubscribes (engine preempts on pressure)")
    ap.add_argument("--mesh", default="",
                    help="DATAxMODEL device mesh, e.g. 2x1 (default: none)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N placeholder CPU devices (0 = mesh size "
                         "when --mesh is set and jax is not yet imported)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are emitted")
    ap.add_argument("--json", action="store_true",
                    help="emit the metrics summary as JSON")
    args = ap.parse_args()

    mesh_shape = None
    if args.mesh:
        try:
            mesh_shape = tuple(int(x) for x in args.mesh.lower().split("x"))
            assert len(mesh_shape) == 2
        except (ValueError, AssertionError):
            ap.error(f"--mesh expects DATAxMODEL (e.g. 2x1), got {args.mesh!r}")
    # must happen before the first jax import: CPU hosts need placeholder
    # devices to build the mesh (same bootstrap as launch/train.py --devices)
    n_dev = args.devices or (
        mesh_shape[0] * mesh_shape[1] if mesh_shape else 0)
    if n_dev > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev}")

    import numpy as np
    from repro.configs import MeshConfig, ServeConfig, get_config
    from repro.serving import ServingEngine

    cfg = get_config(args.arch, smoke=args.smoke)
    serve_cfg = ServeConfig(
        max_batch=args.batch, max_queue=args.max_queue,
        max_seq_len=args.prompt_len + args.max_new,
        max_new_tokens=args.max_new, policy=args.policy,
        prefill_chunk=args.prefill_chunk, decode_steps=args.decode_steps,
        kv_layout=args.kv_layout, page_size=args.page_size,
        num_pages=args.num_pages)
    mesh_cfg = None
    if mesh_shape is not None:
        mesh_cfg = MeshConfig(shape=mesh_shape, axis_names=("data", "model"))

    engine = ServingEngine(cfg, serve_cfg, mesh_cfg=mesh_cfg)
    rng = np.random.default_rng(0)
    lengths = rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1,
                           size=args.requests)
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)) for l in lengths]

    stream = None
    if args.stream:
        def stream(rid, tok, done):
            print(f"  req {rid} -> {tok}{'  [done]' if done else ''}",
                  flush=True)

    outs = engine.generate(prompts, args.max_new, stream=stream)
    s = engine.metrics.summary()
    if args.json:
        print(json.dumps(s, indent=2))
    else:
        print(f"served {s['completed']}/{args.requests} requests, "
              f"{s['tokens_out']} tokens in {s['elapsed_s']:.2f}s "
              f"({s['tokens_per_sec']:.1f} tok/s)")
        print(f"  ttft   p50 {s['ttft_p50_s']*1e3:8.1f} ms   "
              f"p99 {s['ttft_p99_s']*1e3:8.1f} ms")
        print(f"  itl    p50 {s['itl_p50_s']*1e3:8.1f} ms   "
              f"p99 {s['itl_p99_s']*1e3:8.1f} ms")
        print(f"  queue  max {s['queue_depth_max']}  "
              f"preemptions {s['preemptions']}  rejected {s['rejected']}")
        layout = "paged" if engine.paged else "slotted"
        print(f"  kv     {layout}  peak {s['kv_bytes_peak']/1e6:.2f} MB  "
              f"(slotted pool would pin {s['kv_bytes_slotted']/1e6:.2f} MB)")
        for i, toks in enumerate(outs):
            print(f"  req {i}: {toks[:8]}{'...' if len(toks) > 8 else ''}")


if __name__ == "__main__":
    main()
