"""Admission + batching scheduler for the continuous-batching engine.

Pure host-side logic (no jax): a bounded request queue plus the policy that
decides which waiting requests are prefilled into free KV slots each engine
cycle, and — under the ``priority`` policy — which running request to
preempt when something more urgent is waiting.

Policies
  * ``fcfs``      — strict arrival order, no preemption.
  * ``priority``  — higher ``priority`` first; ties broken by earlier
                    ``deadline`` (None = no deadline = latest), then arrival.
                    A waiting request with strictly higher priority than the
                    lowest-priority running one preempts it: the victim's
                    slot is evicted and the victim re-queued with its
                    generated tokens folded into the prompt, so its eventual
                    output is unchanged (greedy decode is deterministic, and
                    sampled decode keys its PRNG by absolute token index —
                    see ``serving/sampling.py`` — so resume replays exactly).

``max_prefills_per_step`` (formerly ``prefill_chunk``, kept as a deprecated
``ServeConfig`` alias) bounds how many *requests* may start prefilling per
cycle — one of two prefill/decode interleaving knobs.  The other,
``prefill_chunk_tokens``, lives in the engine: it splits a single long
prompt into token chunks run across cycles, so neither many short prompts
nor one long prompt can stall running streams' inter-token latency.  The
scheduler only sees the per-request admission bound; token chunking and
prefix-cache admission (pages shared with cached prompts) are engine/pool
concerns.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ServeConfig
from repro.obs import NULL_TRACER
from repro.serving.sampling import GREEDY, SamplingParams


@dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""
    rid: int
    prompt: Tuple[int, ...]               # token ids
    max_new_tokens: int
    priority: int = 0                     # higher = more urgent
    deadline: Optional[float] = None      # absolute time, policy tiebreak
    sampling: SamplingParams = GREEDY     # per-request generation params
    arrival_seq: int = 0                  # monotone admission counter
    # runtime state (owned by the engine)
    tokens: List[int] = field(default_factory=list)   # generated so far
    preempted: int = 0                    # times this request was evicted

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.tokens)

    def resume_prompt(self) -> Tuple[int, ...]:
        """Prompt to re-prefill after preemption: original + generated."""
        return self.prompt + tuple(self.tokens)


class Scheduler:
    """Bounded FCFS/priority queue feeding KV slots.

    The scheduler never touches device state: the engine asks it *which*
    requests to prefill (``next_prefills``) and *which* running request to
    evict (``preemption``); slot bookkeeping itself lives in the KV pool.
    """

    def __init__(self, cfg: ServeConfig, tracer=None):
        cfg.validate()
        self.cfg = cfg
        # queue-side trace events (engine passes its Tracer; NULL when off)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.waiting: List[Request] = []
        self._seq = itertools.count()
        # requeue sequence: monotone *decrementing* so every re-queued
        # request sorts before fresh arrivals AND no two requeues collide
        # (the old ``-1 - preempted`` scheme collided two once-preempted
        # requests at -2 and let a twice-preempted one jump an earlier
        # once-preempted one)
        self._requeue_seq = itertools.count(-1, -1)

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Admit into the waiting queue; False when over ``max_queue``."""
        if len(self.waiting) >= self.cfg.max_queue:
            self.tracer.instant("queue.reject", rid=req.rid)
            return False
        req.arrival_seq = next(self._seq)
        self.waiting.append(req)
        self.tracer.counter("queue_depth", len(self.waiting))
        return True

    def depth(self) -> int:
        return len(self.waiting)

    # -- ordering ----------------------------------------------------------

    def _rank(self, r: Request):
        """Sort key: most-urgent first."""
        if self.cfg.policy == "priority":
            dl = r.deadline if r.deadline is not None else float("inf")
            return (-r.priority, dl, r.arrival_seq)
        return (r.arrival_seq,)

    def _sorted_waiting(self) -> List[Request]:
        return sorted(self.waiting, key=self._rank)

    def peek(self) -> Optional[Request]:
        """Most urgent waiting request without popping it (None if empty)."""
        return self._sorted_waiting()[0] if self.waiting else None

    # -- batching ----------------------------------------------------------

    def next_prefills(self, free_slots: int,
                      skip_rids=frozenset()) -> List[Request]:
        """Pop up to min(free_slots, max_prefills_per_step) requests to
        start prefilling now.

        ``skip_rids`` holds requests that must not be admitted this cycle —
        the pipelined engine passes the rids with device results still in
        flight (a preempted victim's un-retired tokens would be missing
        from its resume prompt).  The guard keeps head-of-line order: a
        skipped head *blocks* admission rather than letting later arrivals
        jump it, matching the synchronous engine's strict ordering; the
        skip clears at the next retire, one cycle later.
        """
        n = min(free_slots, self.cfg.max_prefills_per_step, len(self.waiting))
        if n <= 0:
            return []
        picked = []
        for r in self._sorted_waiting()[:n]:
            if r.rid in skip_rids:
                break
            picked.append(r)
        for r in picked:
            self.waiting.remove(r)
        return picked

    def preemption(self, running: Dict[int, Request]) -> List[Tuple[int, Request]]:
        """(slot, victim) pairs to evict for strictly-higher-priority waiters.

        Only meaningful under the ``priority`` policy and only when
        admission is blocked — no free slot, or (paged pool) too few free
        pages for the most urgent waiter.  At most one victim per waiting
        challenger, and never more victims than ``max_prefills_per_step`` —
        a freed slot the next admission round cannot refill would idle
        while its victim needlessly loses decode progress.  A challenger
        never preempts a peer of equal priority (avoids livelock).
        """
        if self.cfg.policy != "priority" or not running or not self.waiting:
            return []
        victims: List[Tuple[int, Request]] = []
        # running requests, least-urgent first
        by_urgency = sorted(running.items(), key=lambda kv: self._rank(kv[1]),
                            reverse=True)
        challengers = self._sorted_waiting()[:self.cfg.max_prefills_per_step]
        taken = set()
        for ch in challengers:
            for slot, victim in by_urgency:
                if slot in taken:
                    continue
                if ch.priority > victim.priority:
                    victims.append((slot, victim))
                    taken.add(slot)
                    break
            else:
                break       # most-urgent challenger found no victim: stop
        return victims

    def requeue(self, req: Request) -> None:
        """Return a preempted request to the queue (front of its rank class).

        Preempted requests bypass ``max_queue`` — they were already admitted
        once; bouncing them would drop accepted work.  Victims of one
        preemption round arrive here least-urgent-first (``preemption``'s
        order), so the decrementing counter hands the most urgent victim the
        most negative seq: within a rank class, re-queued requests resume in
        their original arrival order.
        """
        req.preempted += 1
        self.push_front(req)

    def drop(self, req: Request) -> bool:
        """Remove a waiting request outright (no requeue).  The pipelined
        engine needs this when a preempted-and-requeued request's in-flight
        tokens turn out to *complete* it at retire time — the finished
        request must not be re-admitted and re-served."""
        try:
            self.waiting.remove(req)
            return True
        except ValueError:
            return False

    def push_front(self, req: Request) -> None:
        """Put a popped-but-not-admitted request back at the queue head
        (no preemption bookkeeping) — e.g. when the paged pool briefly has
        a slot but not the pages for its prompt."""
        req.arrival_seq = next(self._requeue_seq)
        self.waiting.append(req)
        self.tracer.instant("queue.push_front", rid=req.rid,
                            preempted=req.preempted)
