"""Continuous-batching inference subsystem (the serving counterpart of the
paper's user-transparent training runtime).

Users write the model (registry bundles expose ``serve_prefill_fn`` /
``decode_fn``); the engine owns batching, slotted KV-cache management,
scheduling, and mesh sharding — selected by config, not user code.
"""
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import SlotKVCachePool, pool_pspecs
from repro.serving.layouts import KVLayout, layout_for
from repro.serving.metrics import ServingMetrics
from repro.serving.paged import PagedKVCachePool, paged_pspecs
from repro.serving.sampling import GREEDY, SamplingParams
from repro.serving.scheduler import Request, Scheduler
from repro.serving.spec import DrafterPool, NGramDrafter

__all__ = ["ServingEngine", "SlotKVCachePool", "PagedKVCachePool",
           "KVLayout", "layout_for", "pool_pspecs", "paged_pspecs",
           "ServingMetrics", "Request", "Scheduler", "SamplingParams",
           "GREEDY", "NGramDrafter", "DrafterPool"]
