"""Speculative decoding: n-gram drafting + deterministic-replay verify.

The engine speculates in three places that mirror its pipeline:

* **draft** (pure host, between plan and submit): an ``NGramDrafter``
  per request proposes up to ``spec_tokens`` continuation tokens by
  suffix-matching the request's own history (prompt + generated) —
  prompt-lookup decoding, no draft model, no device work.
* **verify** (submit): the drafts ride one paged-prefill-style forward
  (``paged_verify_fn``) that scatters their KV straight into the
  request's pages and returns logits at *every* drafted position.
* **accept** (retire): the longest draft prefix that matches what the
  engine itself would have emitted is kept; the slot's ``pos`` and
  page-table tail are rewound past the last accepted token
  (``PagedKVCachePool.rewind``), freeing pages the rejected suffix
  touched.

**Deterministic replay.**  Verification recomputes, at each drafted
position, exactly the token the non-speculative engine would emit
there — ``sample_tokens`` over the verify logits with the request's
own params and counter-based PRNG index (argmax when temperature is
0) — and accepts draft ``d_j`` iff it equals that token.  Because the
sampler is a pure function of (logits, params, position), spec-on is
**token-identical to spec-off for greedy and sampled requests alike**;
nothing distributional is traded away: for a deterministic
(point-mass) drafter like n-gram lookup, standard residual
accept-reject degenerates to exactly this rule.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

__all__ = ["NGramDrafter", "DrafterPool"]


class NGramDrafter:
    """Suffix-table drafter over one request's (prompt + generated) history.

    Indexes every ``ngram``-gram that has a known continuation, keyed to
    its most recent occurrence; ``propose`` looks up the current suffix
    and replays up to ``k`` tokens that followed it last time.  The
    index grows incrementally (history only ever extends — preemption
    resumes with prompt + generated, never a shorter sequence).
    """

    def __init__(self, ngram: int = 2):
        if ngram < 1:
            raise ValueError(f"ngram={ngram!r} must be an int >= 1")
        self.ngram = ngram
        self._index: Dict[Tuple[int, ...], int] = {}
        self._seen = 0              # gram end positions indexed so far

    def propose(self, history: Sequence[int], k: int) -> Tuple[int, ...]:
        """Up to ``k`` draft tokens continuing ``history`` (may be empty)."""
        n = self.ngram
        hist = list(history)
        # index grams ending at i (continuation = hist[i], so i < len)
        for i in range(max(self._seen, n), len(hist)):
            self._index[tuple(hist[i - n:i])] = i
        self._seen = max(self._seen, len(hist))
        if k <= 0 or len(hist) < n:
            return ()
        j = self._index.get(tuple(hist[-n:]))
        if j is None:
            return ()
        # Replay from the match, re-anchoring whenever the replay runs off
        # the end of recorded history: the most recent occurrence of a
        # periodic suffix sits close to the end, so a plain
        # ``hist[j:j + k]`` slice would return 1-2 tokens however large
        # ``k`` is.  The working copy extends with the drafted tokens so
        # the re-anchor suffix tracks the speculation; the *index* only
        # ever holds real history (a rejected draft poisons nothing).
        real = len(hist)
        work = hist                     # extended in place with drafts
        out = []
        while len(out) < k:
            tok = work[j]
            out.append(tok)
            work.append(tok)
            j += 1
            if j >= real:
                j = self._index.get(tuple(work[-n:]))
                if j is None:
                    break
        return tuple(out)


class DrafterPool:
    """Per-request drafters, keyed by rid; dropped when the request ends."""

    def __init__(self, ngram: int = 2):
        self.ngram = ngram
        self._by_rid: Dict[int, NGramDrafter] = {}

    def propose(self, rid: int, history: Sequence[int],
                k: int) -> Tuple[int, ...]:
        d = self._by_rid.get(rid)
        if d is None:
            d = self._by_rid[rid] = NGramDrafter(self.ngram)
        return d.propose(history, k)

    def drop(self, rid: int) -> None:
        self._by_rid.pop(rid, None)
