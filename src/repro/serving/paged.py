"""Page-granular KV-cache pool (vLLM-style) for continuous-batching decode.

Where ``SlotKVCachePool`` preallocates ``max_seq_len`` of K/V per slot —
cache memory set by the worst-case sequence — this pool owns one *global*
page pool per layer (``[L, P, page_size, KV, hd]``), a free-page allocator,
and a per-slot page table.  Pages are allocated lazily as a request's
position crosses page boundaries and returned on eviction, so the bytes
*held* track the tokens actually cached, and ``num_pages`` can provision
less than ``max_batch x max_seq_len`` (oversubscription; the engine
preempts on page pressure).

Page 0 is a reserved **trash page**: never allocated, it absorbs the
writes of slots without a request (their page tables are all-zero) and of
insert padding, so the batched decode keeps its fixed shape without
masking any scatter.

Device state is three pieces, all fixed-shape (decode compiles once):
  * ``pages``   {"k","v"}: [L, P, ps, KV, hd]  — donated through decode
  * page table  [slots, pages_per_slot] int32  — host-owned (numpy),
    re-uploaded per decode step (tiny; allocation is host-side bookkeeping)
  * ``pos``     [slots] int32                  — tokens cached per slot

Token *t* of a slot lives at page ``table[slot, t // ps]``, offset
``t % ps`` — contiguous, no ring wrap-around, which is why only
``attn_kind == "full"`` families page (see registry.paged_decode_fn).

Eviction hygiene: freed pages go back to the allocator without device-side
blanking — a page is only reachable through a table that points at it, the
next tenant's insert overwrites every slot it reads (the in-page tail past
``pos`` is masked by length), so stale K/V can never influence another
request.  The aliasing property (no page in two tables) is tested.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

P_ = jax.sharding.PartitionSpec


def paged_pspecs(pool_structs, *, model_size: int = 1):
    """PartitionSpec tree for the page pool [L, P, ps, KV, hd]: KV-head dim
    -> "model" when divisible (else head_dim); pages replicate — any slot's
    pages live anywhere, so there is no data-axis to shard them over."""

    def rule(leaf):
        spec = [None] * leaf.ndim
        if model_size > 1 and leaf.ndim == 5:
            if leaf.shape[3] % model_size == 0:
                spec[3] = "model"
            elif leaf.shape[4] % model_size == 0:
                spec[4] = "model"
        return P_(*spec)

    return jax.tree.map(rule, pool_structs)


class PagedKVCachePool:
    """Global page pool + free-page allocator + per-slot page tables.

    ``blank_page_fn()`` must return ``ModelBundle.init_decode_state(1,
    page_size)`` — its "k"/"v" leaves ([L, 1, ps, KV, hd]) are the
    one-page template the pool tiles ``num_pages`` times.  Prefill states
    handed to ``insert`` must be sized ``cache_len == padded_len``
    (``pages_per_slot * page_size``) so they scatter page-by-page.
    """

    def __init__(self, num_slots: int, page_size: int, max_seq_len: int,
                 blank_page_fn, *, num_pages: int = 0, mesh=None,
                 model_size: int = 1):
        assert num_slots >= 1 and page_size >= 1
        self.num_slots = num_slots
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.pages_per_slot = -(-max_seq_len // page_size)
        self.padded_len = self.pages_per_slot * page_size
        worst = num_slots * self.pages_per_slot + 1          # +1 trash page
        self.num_pages = num_pages or worst
        if self.num_pages < self.pages_per_slot + 1:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold one request "
                f"(pages_per_slot={self.pages_per_slot} + trash page)")
        self.mesh = mesh

        blank = blank_page_fn()
        if not all(k in blank for k in ("k", "v")):
            raise ValueError("paged pool needs a k/v attention cache; "
                             "got leaves " + str(sorted(blank)))
        one = {"k": blank["k"], "v": blank["v"]}             # [L,1,ps,KV,hd]
        P = self.num_pages

        def grow(x):
            return jnp.broadcast_to(
                x[:, 0][:, None], (x.shape[0], P) + x.shape[2:]).copy()

        if mesh is not None:
            structs = jax.eval_shape(lambda t: jax.tree.map(grow, t), one)
            self.pspecs = paged_pspecs(structs, model_size=model_size)
            self.shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), self.pspecs)
            out_sh = {"out_shardings": self.shardings}
        else:
            self.pspecs = None
            self.shardings = None
            out_sh = {}

        def _insert(pages, one_state, ids):
            """Scatter a contiguous prefill cache into pages ``ids``.

            one_state k/v: [L, 1, padded_len, KV, hd]; ids
            [pages_per_slot] int32 — entries past the prompt's pages point
            at the trash page and receive the (blank) tail chunks.
            """
            def put(pool, x):
                xr = x[:, 0].reshape((x.shape[0], self.pages_per_slot,
                                      page_size) + x.shape[3:])
                return pool.at[:, ids].set(xr.astype(pool.dtype))
            return {"k": put(pages["k"], one_state["k"]),
                    "v": put(pages["v"], one_state["v"])}

        self._insert = jax.jit(_insert, donate_argnums=(0,), **out_sh)
        self.pages = jax.jit(lambda t: jax.tree.map(grow, t), **out_sh)(one)

        # bytes of one page across layers and k+v (for telemetry)
        self.page_bytes = sum(
            leaf.nbytes // P for leaf in jax.tree.leaves(self.pages))

        # -- host bookkeeping ---------------------------------------------
        self._free_slots: List[int] = list(range(num_slots))
        self._free_pages: List[int] = list(range(1, P))      # 0 = trash
        self.owner: Dict[int, int] = {}                      # slot -> rid
        self.held: Dict[int, List[int]] = {}                 # slot -> pages
        self.tables = np.zeros((num_slots, self.pages_per_slot), np.int32)
        self.pos = np.zeros((num_slots,), np.int32)
        self.pages_allocated = 0                             # lifetime counters
        self.pages_freed = 0
        self.peak_pages_held = 0

    # -- host bookkeeping --------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.owner)

    @property
    def pages_held(self) -> int:
        return sum(len(p) for p in self.held.values())

    def can_admit(self, n_tokens: int) -> bool:
        """Is there a slot and enough free pages for an n_tokens prefill?"""
        need = -(-n_tokens // self.page_size)
        return bool(self._free_slots) and len(self._free_pages) >= need

    def _take_page(self, slot: int) -> Optional[int]:
        if not self._free_pages:
            return None
        pid = self._free_pages.pop(0)
        self.held[slot].append(pid)
        self.tables[slot, len(self.held[slot]) - 1] = pid
        self.pages_allocated += 1
        return pid

    # -- engine API --------------------------------------------------------

    def insert(self, rid: int, one_state, n_tokens: int) -> Optional[int]:
        """Place a prefilled cache (cache_len == padded_len) into a free
        slot, allocating ceil(n_tokens / page_size) pages.  None when slots
        or pages are exhausted (caller re-queues the request)."""
        if not self.can_admit(n_tokens):
            return None
        slot = self._free_slots.pop(0)
        assert slot not in self.owner, f"slot {slot} double-assigned"
        self.owner[slot] = rid
        self.held[slot] = []
        self.tables[slot] = 0
        for _ in range(-(-n_tokens // self.page_size)):
            self._take_page(slot)
        self.pos[slot] = n_tokens
        one_kv = {"k": one_state["k"], "v": one_state["v"]}
        self.pages = self._insert(self.pages, one_kv,
                                  jnp.asarray(self.tables[slot]))
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return slot

    def evict(self, slot: int) -> int:
        """Free a slot: its pages return to the allocator (no device
        blanking needed — see module docstring on hygiene)."""
        rid = self.owner.pop(slot)
        freed = self.held.pop(slot)
        self.pages_freed += len(freed)
        self._free_pages.extend(freed)
        self._free_pages.sort()
        self.tables[slot] = 0
        self.pos[slot] = 0
        self._free_slots.append(slot)
        self._free_slots.sort()
        return rid

    def ensure_decode_capacity(self) -> List[int]:
        """Lazily allocate so every active slot can write position ``pos``
        (the next decode token).  Returns the slots that could not be
        extended — the engine preempts to relieve the pressure."""
        starved = []
        for slot in self.active_slots:
            need = int(self.pos[slot]) // self.page_size + 1
            while len(self.held[slot]) < need:
                if self._take_page(slot) is None:
                    starved.append(slot)
                    break
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return starved

    def decode_view(self) -> Tuple[jax.Array, jax.Array]:
        """(page_table, pos) device operands for one decode step."""
        return jnp.asarray(self.tables), jnp.asarray(self.pos)

    def advance(self) -> None:
        """One decode step happened: every active slot cached one token."""
        for slot in self.owner:
            self.pos[slot] += 1

    # -- telemetry ---------------------------------------------------------

    def kv_bytes_held(self) -> int:
        return self.pages_held * self.page_bytes

    def kv_bytes_capacity(self) -> int:
        return (self.num_pages - 1) * self.page_bytes

    def kv_bytes_slotted(self) -> int:
        """K/V bytes a slot-granular pool would statically preallocate for
        the same config (max_seq_len tokens per slot, no page padding)."""
        return self.num_slots * self.max_seq_len * (self.page_bytes
                                                    // self.page_size)
