"""Page-granular KV-cache pool (vLLM-style) for continuous-batching decode.

Where ``SlotKVCachePool`` preallocates a full decode cache per slot —
cache memory set by the worst case — this pool owns one *global* page pool
per layer for each cache leaf its ``KVLayout`` names (per-head ``k``/``v``
pages for GQA, latent ``ckv``/``krope`` pages for MLA), a free-page
allocator, and a per-slot page table.  Pages are allocated lazily as a
request's position crosses page boundaries and returned on eviction, so
the bytes *held* track the tokens actually cached, and ``num_pages`` can
provision less than the worst case (oversubscription; the engine preempts
on page pressure).

Page 0 is a reserved **trash page**: never allocated, it absorbs the
writes of slots without a request (their page tables are all-zero), of
insert padding, and of masked prefill-bucket tails, so the batched decode
and bucketed prefill keep their fixed shapes without masking any scatter.

**Layouts** (``repro.serving.layouts.KVLayout``) own the physical page
geometry:

  * contiguous layouts ("kv", "latent") — token ``t`` of a slot lives at
    page ``table[slot, t // ps]``, offset ``t % ps``, forever;
  * ring layouts ("window", sliding-window/local attention) — the table is
    a ring of ``window // ps`` cells; token ``t`` lives at cell
    ``(t % window) // ps`` and a cell's page is *reused in place* as the
    sequence wraps, so a slot never holds more than ``window`` tokens —
    the paged twin of the slotted ring cache.  Reusing a cell whose page
    is shared (prefix-cache mapped) or indexed triggers copy-on-write (or
    a plain drop + fresh page when the whole block is being rewritten), so
    rotation can never corrupt another slot's — or the index's — K/V.

**Prefix caching** (``enable_prefix_cache``): every page holds a
*reference count* and, once its request's prefill commits, full
page-aligned prompt blocks are registered in a hash-trie index —
``chain_hash(block_0..i) -> page``.  A new request walks the index with
its own prompt blocks and maps every hit read-only (refcount++): those
positions are never re-prefilled and their pages never duplicated.  A
prompt *fully* covered by cached blocks reuses the last block's page
**copy-on-write** so the final token can re-run for its logits — unless
the pool has also memoized that prompt's greedy next token
(``cache_next_token``), in which case the last block maps read-only like
the rest and the admission dispatches nothing at all.  When a page's refcount drops to zero it parks in an
LRU of reusable cached pages and is reclaimed only when the allocator
runs dry; reclaiming (or rotating out) an indexed page leaves a
**phantom** entry — ``(None, parent_hash, tokens)`` — so the chain hash
still verifies through it and the *live tail* of a long windowed prompt
stays matchable: ring layouts map only the blocks still inside the new
request's window (``KVLayout.needed_start``) and count everything before
them as cached anyway (wholly window-masked, no page needed).

Device state is fixed-shape (decode compiles once):
  * ``pages``   {leaf: [L, P, ps, ...]}  — donated through decode
  * page table  [slots, table_width] int32 — host-owned (numpy); packed
    with ``pos`` and the per-slot step budgets into ONE int32 upload per
    decode cycle (``decode_operands`` — dispatch count, not bytes, is
    what a cycle pays for on the host side)
  * ``pos``     [slots] int32            — tokens cached per slot

Eviction hygiene: freed pages go back to the allocator without device-side
blanking — a page is only reachable through a table that points at it, the
next tenant's writes cover every position it reads (tails are masked by
length / ring-position arithmetic), so stale K/V can never influence
another request.  The aliasing property (no *private* page in two tables;
shared pages only ever read) is tested.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import NULL_TRACER
from repro.serving.layouts import (KV_FULL, KVLayout, SCALE_SUFFIX,
                                   quantize_kv)

P_ = jax.sharding.PartitionSpec


def paged_pspecs(pool_structs, *, model_size: int = 1,
                 layout: KVLayout = KV_FULL):
    """PartitionSpec tree for the page pool: each leaf's spec comes from the
    layout (KV-head / head_dim / latent rank -> "model" when divisible;
    pages replicate — any slot's pages live anywhere, so there is no
    data axis to shard them over)."""
    return {name: layout.page_pspec(name, leaf, model_size)
            for name, leaf in pool_structs.items()}


def chain_blocks(tokens: Sequence[int], page_size: int, *,
                 start_block: int = 0, parent: Optional[int] = None):
    """Yield ``(block_idx, block_tokens, parent_hash, chain_hash)`` for each
    full ``page_size``-token block of ``tokens`` from ``start_block`` on.

    ``h_i = hash((h_{i-1}, block_i))``, seeded with ``page_size`` — a
    block's hash commits to the whole prefix, so two chains collide only
    when every leading block matches.  Deterministic within a process (int
    tuples; no PYTHONHASHSEED salt).  ``hash()`` is non-cryptographic, so
    the index additionally stores ``(parent_hash, block_tokens)`` per entry
    and every match is verified against them — a collision degrades to a
    cache miss, never to serving another prompt's K/V.  This generator is
    the ONLY place the chain step lives: lookup (``_plan``), registration
    (``commit_prefix``) and the test helper all walk through it, so the two
    sides of the index cannot drift."""
    h = page_size if parent is None else parent
    for i in range(start_block, len(tokens) // page_size):
        blk = tuple(tokens[i * page_size:(i + 1) * page_size])
        p, h = h, hash((h, blk))
        yield i, blk, p, h


def block_hashes(tokens: Sequence[int], page_size: int) -> List[int]:
    """Chain hashes of the full blocks of ``tokens`` (see chain_blocks)."""
    return [h for _, _, _, h in chain_blocks(tokens, page_size)]


class PagedKVCachePool:
    """Global page pool + refcounted allocator + prefix index + page tables.

    ``blank_page_fn()`` must return ``ModelBundle.init_decode_state(1,
    page_size)`` — the layout's leaves (e.g. "k"/"v" [L, 1, ps, KV, hd] or
    "ckv"/"krope" [L, 1, ps, R]) are the one-page template the pool tiles
    ``num_pages`` times.  Prefill states handed to ``insert`` must be sized
    ``cache_len == padded_len`` (``pages_per_slot * page_size``) so they
    scatter page-by-page; the prefix-cache path (``alloc_prefix`` + the
    engine's paged prefill) bypasses ``insert`` and writes pages in place.
    """

    def __init__(self, num_slots: int, page_size: int, max_seq_len: int,
                 blank_page_fn, *, num_pages: int = 0, mesh=None,
                 model_size: int = 1, enable_prefix_cache: bool = False,
                 layout: Optional[KVLayout] = None, tracer=None):
        assert num_slots >= 1 and page_size >= 1
        # cache events (alloc/COW/ring/LRU/prefix hit-miss) + plan spans go
        # to the engine's tracer; NULL_TRACER keeps every emit a no-op
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.layout = layout or KV_FULL
        self.layout.check_page_size(page_size)
        self.num_slots = num_slots
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.pages_per_slot = -(-max_seq_len // page_size)   # logical blocks
        self.padded_len = self.pages_per_slot * page_size
        self.table_width = self.layout.table_width(self.pages_per_slot,
                                                   page_size)
        worst = num_slots * self.table_width + 1             # +1 trash page
        self.num_pages = num_pages or worst
        if self.num_pages < self.table_width + 1:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold one request "
                f"(table_width={self.table_width} + trash page)")
        self.mesh = mesh
        self.enable_prefix_cache = enable_prefix_cache

        blank = blank_page_fn()
        missing = [k for k in self.layout.data_leaves if k not in blank]
        if missing:
            raise ValueError(
                f"paged pool ({self.layout.name} layout) needs decode-state "
                f"leaves {self.layout.data_leaves}; missing {missing} in "
                + str(sorted(blank)))
        # [L,1,ps,...]; quantized layouts swap each data leaf for an int8
        # page + a per-row fp32 scale leaf here (the bundle's native state
        # never carries scales — the pool owns the storage format)
        one = self.layout.page_template(blank)
        P = self.num_pages

        def grow(x):
            return jnp.broadcast_to(
                x[:, 0][:, None], (x.shape[0], P) + x.shape[2:]).copy()

        if mesh is not None:
            structs = jax.eval_shape(lambda t: jax.tree.map(grow, t), one)
            self.pspecs = paged_pspecs(structs, model_size=model_size,
                                       layout=self.layout)
            self.shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), self.pspecs)
            out_sh = {"out_shardings": self.shardings}
        else:
            self.pspecs = None
            self.shardings = None
            out_sh = {}

        quantized = self.layout.quantized

        def _insert(pages, one_state, ids):
            """Scatter a contiguous prefill cache into pages ``ids``.

            one_state holds the layout's *data* leaves [L, 1, padded_len,
            ...] (the bundle's native fp state — scale leaves exist only in
            the pool); ids [pages_per_slot] int32 — entries past the
            prompt's pages point at the trash page and receive the (blank)
            tail chunks.  Quantized layouts quantize here with the same
            ``quantize_kv`` the incremental write paths use, so an inserted
            token's page bytes match what a chunked prefill would have
            written.
            """
            def chunked(x):
                return x[:, 0].reshape((x.shape[0], self.pages_per_slot,
                                        page_size) + x.shape[3:])
            out = {}
            for n in one_state:
                xr = chunked(one_state[n])
                if quantized:
                    q, s = quantize_kv(xr)
                    out[n] = pages[n].at[:, ids].set(q)
                    out[n + SCALE_SUFFIX] = \
                        pages[n + SCALE_SUFFIX].at[:, ids].set(s)
                else:
                    out[n] = pages[n].at[:, ids].set(xr.astype(pages[n].dtype))
            return out

        def _copy(pages, dst, src):
            """Copy-on-write: duplicate page ``src`` into ``dst`` (every
            layer, every leaf) so the new tenant can overwrite its tail."""
            return {n: pages[n].at[:, dst].set(pages[n][:, src])
                    for n in pages}

        self._insert = jax.jit(_insert, donate_argnums=(0,), **out_sh)
        self._copy = jax.jit(_copy, donate_argnums=(0,), **out_sh)
        self.pages = jax.jit(lambda t: jax.tree.map(grow, t), **out_sh)(one)
        if enable_prefix_cache or self.layout.ring:
            # compile the COW copy now (trash -> trash no-op): the first
            # fully-cached-prompt admission (or ring rotation of a shared
            # cell) must not stall on a jit trace mid-pass
            self.pages = self._copy(self.pages, jnp.asarray(0, jnp.int32),
                                    jnp.asarray(0, jnp.int32))

        # bytes of one page across layers and leaves (for telemetry)
        self.page_bytes = sum(
            leaf.nbytes // P for leaf in jax.tree.leaves(self.pages))
        # fp32-equivalent page bytes (data leaves at 4 B/elt, no scale
        # leaves) — denominator of the quantized savings ratio; equals
        # page_bytes for fp32 pools
        self.page_bytes_fp32 = sum(
            self.pages[n].size * 4 // P for n in self.layout.data_leaves)

        # -- host bookkeeping ---------------------------------------------
        self._free_slots: List[int] = list(range(num_slots))
        self._free_pages: List[int] = list(range(1, P))      # 0 = trash
        self.refcount = np.zeros((P,), np.int32)             # per-page
        self.owner: Dict[int, int] = {}                      # slot -> rid
        self.held: Dict[int, List[int]] = {}                 # slot -> pages
        self._blocks: Dict[int, List[int]] = {}              # logical ids
        self._cells: Dict[int, Dict[int, int]] = {}          # cell -> block
        self.tables = np.zeros((num_slots, self.table_width), np.int32)
        self.pos = np.zeros((num_slots,), np.int32)
        # prefix index: chain hash -> (page | None, parent_hash, tokens) —
        # page None marks a *phantom* (reclaimed / rotated out): the chain
        # still verifies through it, it just has no K/V to map; the stored
        # pair verifies every hit (hash collisions degrade to misses);
        # reverse map page -> chain hash; per-slot commit cursor (next
        # block index, parent hash) so chunked commits hash each token
        # once; and the LRU of refcount-0 pages still indexed
        self._index: Dict[int, Tuple[Optional[int], int,
                                     Tuple[int, ...]]] = {}
        self._block_of_page: Dict[int, int] = {}
        self._commit_cursor: Dict[int, Tuple[int, int]] = {}
        self._cached_lru: "OrderedDict[int, None]" = OrderedDict()
        # plan memo keyed by prompt: steady-state traffic repeats prompts
        # (shared system prompts, resume re-prefills, probe-then-admit in
        # one cycle), so the chain-hash walk runs once per (prompt, index
        # epoch) instead of once per admission attempt.  Entries carry the
        # index version they were computed under and go stale — never
        # wrong — when the index changes; a bounded LRU caps host memory.
        self._index_version = 0
        self._plan_cache: "OrderedDict[Tuple[int, ...], Tuple[int, tuple]]" \
            = OrderedDict()
        self._plan_cache_cap = 512
        # greedy next-token memo: prompt -> the device scalar its prefill
        # argmaxed (see cache_next_token).  Content-addressed truth under
        # greedy decoding, so unlike the plan memo it needs no version —
        # only the LRU cap and clear_prefix_cache bound it.
        self._next_tok: "OrderedDict[Tuple[int, ...], object]" = OrderedDict()
        self.tracer.instant("pool.init", num_pages=self.num_pages,
                            page_size=page_size,
                            table_width=self.table_width,
                            **self.layout.describe())
        self.pages_allocated = 0                             # lifetime counters
        self.pages_freed = 0
        self.peak_pages_held = 0
        self.prefix_hit_pages = 0                            # shared mappings
        self.cow_copies = 0
        self.cached_pages_evicted = 0                        # LRU reclaims

    # -- host bookkeeping --------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.owner)

    @property
    def pages_held(self) -> int:
        """Pages referenced by at least one live slot (shared pages count
        once — that is the point of sharing them)."""
        return int((self.refcount > 0).sum())

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages parked in the prefix-cache LRU (reclaimable)."""
        return len(self._cached_lru)

    def _page_budget(self) -> int:
        """Pages the allocator can hand out right now: content-free pages
        plus reclaimable cached ones."""
        return len(self._free_pages) + len(self._cached_lru)

    def can_admit(self, n_tokens: int) -> bool:
        """Is there a slot and enough free pages for an n_tokens prefill
        (ignoring any prefix sharing — see ``can_admit_prompt``)?  Ring
        layouts cap the need at the table width: later blocks reuse cells
        in place."""
        need = min(-(-n_tokens // self.page_size), self.table_width)
        return bool(self._free_slots) and self._page_budget() >= need

    def can_admit_prompt(self, prompt: Sequence[int]) -> bool:
        """``can_admit`` minus the pages a prefix-cache hit would share."""
        if not self._free_slots:
            return False
        shared, cow_src, _, _, start_blk = self._plan(prompt)
        total = -(-len(prompt) // self.page_size)
        upfront = min(total, start_blk + self.table_width) - start_blk
        return self._alloc_budget(shared, cow_src) >= upfront - len(shared)

    def _alloc_budget(self, shared: List[int], cow_src: Optional[int]) -> int:
        """Allocatable pages for one admission: the global budget minus LRU
        pages this very admission will map/pin (they stop being
        reclaimable the moment they are re-referenced)."""
        pinned = sum(1 for p in shared + ([cow_src] if cow_src is not None
                                          else []) if p in self._cached_lru)
        return self._page_budget() - pinned

    # -- page plumbing -----------------------------------------------------

    def _take_slot(self, rid: int) -> int:
        """Pop a free slot and zero its bookkeeping (table row -> trash)."""
        slot = self._free_slots.pop(0)
        assert slot not in self.owner, f"slot {slot} double-assigned"
        self.owner[slot] = rid
        self.held[slot] = []
        self._blocks[slot] = []
        self._cells[slot] = {}
        self.tables[slot] = 0
        return slot

    def _grab(self) -> Optional[int]:
        """Acquire a raw page: content-free pages first, then reclaim the
        least-recently-used cached page.  Reclaiming leaves a *phantom*
        index entry so the chain hash still verifies through the block."""
        if self._free_pages:
            return self._free_pages.pop(0)
        if self._cached_lru:
            pid, _ = self._cached_lru.popitem(last=False)
            h = self._block_of_page.pop(pid)
            entry = self._index.get(h)
            if entry is not None and entry[0] == pid:
                self._index[h] = (None, entry[1], entry[2])
                self._prune_phantoms()
            self._index_version += 1
            self.cached_pages_evicted += 1
            self.tracer.instant("pool.lru_reclaim", page=pid)
            return pid
        return None

    def _prune_phantoms(self) -> None:
        """Bound the index: phantoms keep chains matchable past reclaimed
        pages, but a steady stream of distinct prompts would otherwise
        grow ``_index`` without limit (pre-phantom behaviour deleted on
        reclaim, capping it at ~num_pages entries).  When phantoms
        outnumber live entries several-fold, drop them all in one sweep —
        chains through them degrade to misses, exactly the old semantics,
        amortized O(1) per reclaim."""
        live = len(self._block_of_page)
        if len(self._index) - live > max(4 * self.num_pages, 4 * live):
            self._index = {h: e for h, e in self._index.items()
                           if e[0] is not None}
            self._index_version += 1

    def _bind(self, slot: int, block: int, pid: int) -> None:
        """Hand a fresh private page to ``slot`` as logical ``block``."""
        self.refcount[pid] = 1
        self.held[slot].append(pid)
        self._blocks[slot].append(block)
        cell = self.layout.cell(block, self.table_width)
        self._cells[slot][cell] = block
        self.tables[slot, cell] = pid
        self.pages_allocated += 1
        self.tracer.instant("pool.page_alloc", page=pid, slot=slot,
                            block=block)

    def _alloc_page(self, slot: int, block: int) -> Optional[int]:
        pid = self._grab()
        if pid is not None:
            self._bind(slot, block, pid)
        return pid

    def _retain_page(self, pid: int) -> None:
        """refcount++; a 0 -> 1 transition pulls the page out of the LRU and
        counts as an allocation, keeping ``pages_allocated == pages_freed``
        a drain invariant even when cached pages cycle through reuse."""
        if self.refcount[pid] == 0:
            self._cached_lru.pop(pid, None)
            self.pages_allocated += 1
        self.refcount[pid] += 1

    def _map_shared(self, slot: int, pid: int, block: int) -> None:
        """Map an indexed page read-only into ``slot`` as ``block``."""
        self._retain_page(pid)
        self.held[slot].append(pid)
        self._blocks[slot].append(block)
        cell = self.layout.cell(block, self.table_width)
        self._cells[slot][cell] = block
        self.tables[slot, cell] = pid
        self.prefix_hit_pages += 1

    def _release_page(self, pid: int) -> None:
        """Drop one reference; at zero the page parks in the LRU when its
        content is indexed (reusable prefix) and frees otherwise."""
        self.refcount[pid] -= 1
        assert self.refcount[pid] >= 0, f"page {pid} refcount underflow"
        if self.refcount[pid] == 0:
            self.pages_freed += 1
            if pid in self._block_of_page:
                self._cached_lru[pid] = None        # most-recent end
                self.tracer.instant("pool.lru_park", page=pid)
            else:
                self._free_pages.append(pid)
                self._free_pages.sort()
                self.tracer.instant("pool.page_free", page=pid)

    def _page_at(self, slot: int, block: int) -> int:
        return self.held[slot][self._blocks[slot].index(block)]

    def _unbind(self, slot: int, block: int) -> None:
        """Drop ``block`` from the slot (ring rotation / full rewrite)."""
        i = self._blocks[slot].index(block)
        pid = self.held[slot].pop(i)
        self._blocks[slot].pop(i)
        cell = self.layout.cell(block, self.table_width)
        if self._cells[slot].get(cell) == block:
            del self._cells[slot][cell]
        if self.tables[slot, cell] == pid:
            self.tables[slot, cell] = 0
        self._release_page(pid)

    def _cow(self, slot: int, block: int, src: int) -> Optional[int]:
        """Copy-on-write ``src`` (shared or indexed) into a fresh private
        page bound as ``block``, releasing the slot's reference to src."""
        dst = self._grab()
        if dst is None:
            return None
        self.pages = self._copy(self.pages, jnp.asarray(dst, jnp.int32),
                                jnp.asarray(src, jnp.int32))
        self.cow_copies += 1
        self.tracer.instant("pool.cow", src=src, dst=dst, slot=slot,
                            block=block)
        # src is mapped at most once per slot: replace it in place
        i = self.held[slot].index(src)
        self.held[slot][i] = dst
        self._blocks[slot][i] = block
        cell = self.layout.cell(block, self.table_width)
        self._cells[slot][cell] = block
        self.tables[slot, cell] = dst
        self.refcount[dst] = 1
        self.pages_allocated += 1
        self._release_page(src)
        return dst

    def _ensure_writable(self, slot: int, lo: int, hi: int) -> bool:
        """Make every page that positions ``lo..hi`` will write privately
        writable: allocate missing blocks, rotate ring cells whose
        incumbent block has wrapped out of the window (reusing a private
        page in place; dropping or copy-on-writing a shared/indexed one),
        and COW a same-block page another slot or the index can still
        read.  Returns False on page starvation (caller preempts)."""
        ps = self.page_size
        for b in range(lo // ps, hi // ps + 1):
            cell = self.layout.cell(b, self.table_width)
            cur = self._cells[slot].get(cell)
            if cur == b:
                pid = self._page_at(slot, b)
                if self.refcount[pid] > 1 or pid in self._block_of_page:
                    if self._cow(slot, b, pid) is None:
                        return False
            elif cur is None:
                if self._alloc_page(slot, b) is None:
                    return False
            else:                       # ring rotation: cur wrapped out
                pid = self._page_at(slot, cur)
                if self.refcount[pid] == 1 and \
                        pid not in self._block_of_page:
                    # private, unindexed: reuse the page in place — the
                    # ring-position arithmetic resolves its mixed old/new
                    # offsets, so no copy and no allocator traffic
                    i = self._blocks[slot].index(cur)
                    self._blocks[slot][i] = b
                    self._cells[slot][cell] = b
                    self.tracer.instant("pool.ring_rotate", slot=slot,
                                        page=pid, old_block=cur, block=b)
                else:
                    # shared/indexed incumbent: COW into a private page and
                    # release the original (it parks in the LRU when
                    # indexed — "rotated out of the window" frees it for
                    # reuse without losing the cached prefix).  The copy is
                    # never skipped, even when the new block rewrites every
                    # offset: a prefill chunk's *early* queries still
                    # attend the old positions through the pre-write
                    # snapshot gather, which reads whatever page the table
                    # holds when the chunk runs.
                    dst = self._grab()
                    if dst is None:
                        return False
                    self.pages = self._copy(self.pages,
                                            jnp.asarray(dst, jnp.int32),
                                            jnp.asarray(pid, jnp.int32))
                    self.cow_copies += 1
                    self.tracer.instant("pool.ring_rotate", slot=slot,
                                        page=pid, old_block=cur, block=b,
                                        cow_dst=dst)
                    self._unbind(slot, cur)
                    self._bind(slot, b, dst)
        return True

    # -- prefix matching ---------------------------------------------------

    def _plan(self, prompt: Sequence[int]
              ) -> Tuple[List[int], Optional[int], int, Tuple[int, int], int]:
        """(shared_pages, cow_src_page, cached_tokens, commit_seed,
        shared_start_block) for ``prompt``; commit_seed = (first block to
        register, its parent chain hash) — ``alloc_prefix`` seeds the
        slot's commit cursor with it, so ``commit_prefix`` never re-hashes
        blocks the match already walked.

        Walks the chain-hash index over the prompt's full blocks, verifying
        each hit's stored (parent_hash, block_tokens) so a ``hash()``
        collision can only miss, never alias another prompt's pages.  The
        walk passes *through* phantom entries (page reclaimed or rotated
        out) — the chain still verifies — and then shrinks the match until
        every block the suffix will actually read
        (``layout.needed_start``..match) has a live page; for ring layouts
        blocks before ``needed_start`` are wholly window-masked, so they
        count as cached without needing any page at all.  A match covering
        the *entire* prompt keeps its last block out of the read-only
        mapping and returns it as ``cow_src`` instead: the final prompt
        token must still run (logits), so that page is duplicated
        copy-on-write and cached_tokens caps at len(prompt) - 1.  Results
        are memoized per prompt (bounded LRU) until the index next
        changes, so a probe (``can_admit_prompt``) followed by the
        admission — and every repeat of a steady-state prompt between
        index changes — re-plans nothing.
        """
        ps = self.page_size
        plen = len(prompt)
        if not self.enable_prefix_cache:
            return [], None, 0, (0, ps), 0
        key = tuple(prompt)
        memo = self._plan_cache.get(key)
        if memo is not None:
            if memo[0] == self._index_version:
                self._plan_cache.move_to_end(key)
                return memo[1]
            del self._plan_cache[key]           # stale: index moved on
        with self.tracer.span("plan", tokens=plen):
            pids: List[Optional[int]] = []
            hashes: List[int] = []
            for _, blk, parent, h in chain_blocks(prompt, ps):
                entry = self._index.get(h)
                if entry is None or entry[1] != parent or entry[2] != blk:
                    break
                pids.append(entry[0])
                hashes.append(h)
            m = len(pids)
            total_full = plen // ps
            while m:
                full = (m == total_full and m * ps == plen)
                cached = plen - 1 if full else m * ps
                start_blk = self.layout.needed_start(cached, ps)
                dead = [i for i in range(start_blk, m) if pids[i] is None]
                if not dead:
                    break
                m = min(dead)       # truncate below the oldest dead block
            if not m:
                out = [], None, 0, (0, ps), 0
            elif m == total_full and m * ps == plen:
                # the shared read-only blocks end one short of the match;
                # the COW block itself is already indexed, so commits
                # resume there
                seed = (m - 1, hashes[m - 2] if m > 1 else ps)
                out = pids[start_blk:m - 1], pids[m - 1], plen - 1, seed, \
                    start_blk
            else:
                out = pids[start_blk:m], None, m * ps, (m, hashes[m - 1]), \
                    start_blk
            self._plan_cache[key] = (self._index_version, out)
            if len(self._plan_cache) > self._plan_cache_cap:
                self._plan_cache.popitem(last=False)
        return out

    # -- engine API --------------------------------------------------------

    def alloc_prefix(self, rid: int, prompt: Sequence[int], *,
                     use_memo: bool = True) -> Optional[Tuple[int, int]]:
        """Allocate a slot for ``prompt``, mapping the longest cached
        page-aligned prefix read-only and private pages for the rest.

        Returns (slot, cached_tokens) — the engine prefills only positions
        ``cached_tokens..len(prompt)-1`` — or None when slots or pages run
        short (caller re-queues the request).  ``pos`` is set to the full
        prompt length up front; the engine masks the slot out of decode
        until its chunked prefill completes.  Ring layouts allocate at most
        one table-width of pages up front: later blocks reuse cells in
        place (``prepare_chunk`` rotates them ahead of each write).

        ``use_memo=False`` skips the greedy next-token memo promotion:
        sampled requests must re-run the last prompt token for its
        logits (the memo is the *greedy* continuation), so their full
        hits stay at ``len(prompt) - 1`` cached tokens with a COW last
        page.
        """
        plen = len(prompt)
        shared, cow_src, cached, seed, start_blk = self._plan(prompt)
        if use_memo and cow_src is not None and cached == plen - 1 and \
                self.cached_next_token(prompt) is not None:
            # full hit with a remembered next token: the last block joins
            # the read-only mapping like every other — nothing re-runs, so
            # nothing writes into a shared page and the COW the last-token
            # replay would have forced disappears (see cache_next_token).
            # cached == len(prompt) tells the engine to skip prefill
            # entirely and seed decode from the memoized token.
            shared = shared + [cow_src]
            cow_src = None
            cached = plen
        total = -(-plen // self.page_size)
        upfront_end = min(total, start_blk + self.table_width)
        need = (upfront_end - start_blk) - len(shared)
        if not self._free_slots or \
                self._alloc_budget(shared, cow_src) < need:
            return None
        if cached:
            self.tracer.instant("pool.prefix_hit", rid=rid,
                                cached_tokens=cached,
                                shared_pages=len(shared),
                                cow=cow_src is not None)
        elif self.enable_prefix_cache:
            self.tracer.instant("pool.prefix_miss", rid=rid,
                                prompt_tokens=plen)
        slot = self._take_slot(rid)
        # the commit cursor resumes after the matched prefix — blocks the
        # plan walked are never re-hashed by commit_prefix
        self._commit_cursor[slot] = seed
        blk = start_blk
        for pid in shared:
            self._map_shared(slot, pid, blk)
            blk += 1
        if cow_src is not None:
            # pin the source so this alloc's own page grabs cannot reclaim
            # it out of the LRU before the device copy lands
            self._retain_page(cow_src)
            dst = self._alloc_page(slot, blk)
            self.pages = self._copy(self.pages, jnp.asarray(dst, jnp.int32),
                                    jnp.asarray(cow_src, jnp.int32))
            self.cow_copies += 1
            self._release_page(cow_src)
            blk += 1
        while blk < upfront_end:
            self._alloc_page(slot, blk)
            blk += 1
        self.pos[slot] = plen
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return slot, cached

    def prepare_chunk(self, slot: int, start: int, end: int) -> bool:
        """Make the pages positions ``start..end`` (one prefill chunk) will
        write privately writable — ring rotation, COW of shared or indexed
        incumbents.  Contiguous layouts preallocated at admission, so this
        sweep is a cheap no-op there.  False on page starvation (the
        caller preempts to relieve the pressure)."""
        ok = self._ensure_writable(slot, start, end)
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return ok

    def commit_prefix(self, slot: int, prompt: Sequence[int]) -> None:
        """Register the slot's now-written full prompt blocks in the index
        (first writer wins; later identical blocks stay private and simply
        free on eviction — except that a live page *resurrects* a phantom
        entry for its block).  Chunked prefill calls this after every chunk
        with a growing prefix; the per-slot cursor resumes the chain hash
        where the last call stopped, so each token is hashed exactly once
        per admission.  Ring layouts register a block's page only while the
        slot still holds it — a block that has already rotated out of the
        window leaves a phantom entry, keeping the chain (and the live
        tail) matchable."""
        if not self.enable_prefix_cache:
            return
        ps = self.page_size
        start, parent = self._commit_cursor.get(slot, (0, ps))
        cursor = (start, parent)
        for i, blk, p, h in chain_blocks(prompt, ps, start_block=start,
                                         parent=parent):
            pid = (self._page_at(slot, i) if i in self._blocks[slot]
                   else None)
            entry = self._index.get(h)
            if entry is None or (entry[0] is None and pid is not None):
                self._index[h] = (pid, p, blk)
                if pid is not None:
                    self._block_of_page[pid] = h
                self._index_version += 1
            cursor = (i + 1, h)
        self._commit_cursor[slot] = cursor

    def alloc_for_insert(self, rid: int, n_tokens: int) -> Optional[int]:
        """Host half of the non-sharing admission: take a slot and allocate
        ceil(n_tokens / page_size) private pages for it, before any prefill
        has run.  None when slots or pages are exhausted (caller re-queues
        the request).  Splitting allocation from the device scatter lets a
        pipelined engine make the placement decision in its plan phase and
        dispatch ``insert_state`` at submit.  Contiguous layouts only — a
        ring cache has no padded contiguous image (the prefix path,
        ``alloc_prefix`` + paged prefill, serves ring layouts)."""
        if self.layout.ring:
            raise ValueError(
                "ring (windowed) layouts prefill straight into pages via "
                "alloc_prefix + PagedPrefillContract; the contiguous "
                "insert path cannot represent a ring cache")
        if not self.can_admit(n_tokens):
            return None
        slot = self._take_slot(rid)
        for b in range(-(-n_tokens // self.page_size)):
            self._alloc_page(slot, b)
        self.pos[slot] = n_tokens
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return slot

    def insert_state(self, slot: int, one_state) -> None:
        """Device half: scatter a prefilled cache (cache_len == padded_len)
        into the pages ``alloc_for_insert`` bound to ``slot``.  The scatter
        writes every table entry, so the slot must hold only private pages
        (which ``alloc_for_insert`` guarantees)."""
        one_kv = {n: one_state[n] for n in self.layout.data_leaves}
        self.pages = self._insert(self.pages, one_kv,
                                  jnp.asarray(self.tables[slot]))

    def insert(self, rid: int, one_state, n_tokens: int) -> Optional[int]:
        """One-shot admission: ``alloc_for_insert`` + ``insert_state``."""
        slot = self.alloc_for_insert(rid, n_tokens)
        if slot is not None:
            self.insert_state(slot, one_state)
        return slot

    def evict(self, slot: int) -> int:
        """Free a slot: every mapped page drops one reference; pages whose
        content is indexed park in the prefix LRU instead of freeing (no
        device blanking either way — see module docstring on hygiene)."""
        rid = self.owner.pop(slot)
        for pid in self.held.pop(slot):
            self._release_page(pid)
        self._blocks.pop(slot, None)
        self._cells.pop(slot, None)
        self._commit_cursor.pop(slot, None)
        self.tables[slot] = 0
        self.pos[slot] = 0
        self._free_slots.append(slot)
        self._free_slots.sort()
        return rid

    def clear_prefix_cache(self) -> None:
        """Invalidate the prefix index: every refcount-0 cached page returns
        to the free list and no future request can map a previously cached
        block.  Live slots keep serving off their mapped pages — but those
        pages are de-indexed too, so they free (rather than park) on
        eviction.  Call when cached K/V stops being valid (weight updates,
        layout switches) or to measure cold-start behaviour on a warm
        engine."""
        self.tracer.instant("pool.prefix_clear",
                            cached_pages=len(self._cached_lru),
                            index_entries=len(self._index))
        self._free_pages.extend(self._cached_lru)
        self._free_pages.sort()
        self._cached_lru.clear()
        self._index.clear()
        self._block_of_page.clear()
        # the version bump alone invalidates memoized plans lazily; drop
        # them eagerly too so a cleared cache frees the host memory as well
        self._index_version += 1
        self._plan_cache.clear()
        self._next_tok.clear()

    def cache_next_token(self, prompt: Sequence[int], tok) -> None:
        """Remember the greedy token that follows ``prompt`` — the device
        scalar its prefill argmaxed, stored WITHOUT syncing.  Greedy
        decoding is deterministic, so (prompt -> next token) is
        content-addressed truth: a later admission whose prompt is fully
        covered by cached blocks skips its last-token replay — and the COW
        of the shared page that replay would have written into — and seeds
        decode straight from the memo (``alloc_prefix`` reports
        ``cached == len(prompt)``).  That turns a steady-state repeat
        admission from two device dispatches (copy + bucketed 1-token
        prefill) into zero."""
        if not self.enable_prefix_cache:
            return
        key = tuple(int(t) for t in prompt)
        self._next_tok[key] = tok
        self._next_tok.move_to_end(key)
        if len(self._next_tok) > self._plan_cache_cap:
            self._next_tok.popitem(last=False)

    def cached_next_token(self, prompt: Sequence[int]):
        """The memoized greedy next token for ``prompt`` (device scalar),
        or None."""
        return self._next_tok.get(tuple(int(t) for t in prompt))

    def ensure_decode_capacity(self, skip=(), steps=None) -> List[int]:
        """Make every active slot able to write its next decode span:
        positions ``pos .. pos + steps[slot] - 1`` (``steps`` maps slot ->
        span length; absent or None means 1 — the single-step legacy
        shape).  Lazily allocates the pages a contiguous slot's next blocks
        need; rotates / COWs the ring cells a windowed slot wraps into —
        the multi-block sweep is the same ``_ensure_writable`` walk chunked
        prefill uses, so a ``decode_steps``-long on-device scan can write
        its whole span into prepared private pages.  Returns the slots
        that could not be extended — the engine preempts to relieve the
        pressure.  Slots in ``skip`` (still prefilling, or masked out of
        this cycle's scan) are left alone."""
        starved = []
        for slot in self.active_slots:
            if slot in skip:
                continue
            n = 1 if steps is None else int(steps.get(slot, 1))
            if n <= 0:
                continue
            pos = int(self.pos[slot])
            if not self._ensure_writable(slot, pos, pos + n - 1):
                starved.append(slot)
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return starved

    def safe_decode_span(self, slot: int, n: int) -> int:
        """Longest prefix of the decode span ``pos..pos+n-1`` whose writes
        need no ring rotation: every block is already bound to the slot or
        lands in a free cell.  The pipelined engine caps a chunk-completing
        slot's *same-cycle* decode span with this — its chunk's blocks are
        only committed to the prefix index at submit, so a rotation planned
        *before* that would see an unindexed incumbent and rename its page
        in place, stranding the just-prefilled block outside the index
        (content stays correct; the cached prefix would be silently lost).
        One cycle later the blocks are indexed and rotation parks them in
        the LRU as usual.  Contiguous layouts never rotate: ``n``."""
        if not self.layout.ring:
            return n
        ps = self.page_size
        pos = int(self.pos[slot])
        for k in range(n):
            b = (pos + k) // ps
            cur = self._cells[slot].get(self.layout.cell(b, self.table_width))
            if cur is not None and cur != b:
                return k
        return n

    def decode_operands(self, limits: Dict[int, int],
                        mask_slots=()) -> jax.Array:
        """One packed ``[slots, table_width + 2]`` int32 device operand for
        a decode dispatch: the page table, per-slot position and per-slot
        step budget travel as a single upload and are sliced apart inside
        the jitted scan (free — XLA fuses the slices).  Packing matters
        because at serving batch sizes the per-cycle cost is *dispatch
        count*, not bytes: one ``device_put`` here replaces the three
        (table, pos, limits) the unpacked path paid every cycle.  Slots in
        ``mask_slots`` (mid-prefill or not scheduled this cycle) present an
        all-trash table, pos 0 and budget 0, so the fixed-shape decode can
        run while they fill."""
        packed = np.empty((self.num_slots, self.table_width + 2), np.int32)
        packed[:, :-2] = self.tables
        packed[:, -2] = self.pos
        packed[:, -1] = [limits.get(s, 0) for s in range(self.num_slots)]
        if mask_slots:
            packed[list(mask_slots)] = 0
        return jnp.asarray(packed)

    def rewind(self, slot: int, new_pos: int) -> None:
        """Roll a slot's position back after a rejected speculative suffix.

        The speculative verify advanced ``pos`` optimistically past its
        drafted span; positions ``new_pos..`` now hold unverified K/V
        that nothing will ever read (decode attends ``0..pos`` and the
        next writes cover them — the same hygiene argument as page
        reuse, see module docstring), so only host bookkeeping moves:
        ``pos`` drops to ``new_pos`` and — contiguous layouts — blocks
        wholly past the new position unbind, freeing their pages.  Ring
        layouts keep their cells bound: the verify span was planned
        rotation-free (``safe_decode_span``), so every touched block is
        already the cell's incumbent and will simply be rewritten in
        place as the sequence re-grows."""
        assert new_pos >= 1, new_pos
        if not self.layout.ring:
            last_blk = (new_pos - 1) // self.page_size
            for b in [b for b in self._blocks[slot] if b > last_blk]:
                self._unbind(slot, b)
        self.pos[slot] = new_pos

    def advance(self, skip=(), steps=None) -> None:
        """A decode dispatch happened: every decoding slot cached
        ``steps[slot]`` tokens (1 when ``steps`` is None — the legacy
        single-step shape).  The pipelined engine calls this at submit
        time: the host position is deterministic once the span is planned,
        so the next cycle's plan can run against it while the device step
        is still in flight."""
        for slot in self.owner:
            if slot not in skip:
                self.pos[slot] += (1 if steps is None
                                   else int(steps.get(slot, 0)))

    # -- telemetry ---------------------------------------------------------

    def kv_bytes_held(self) -> int:
        return self.pages_held * self.page_bytes

    def kv_bytes_capacity(self) -> int:
        return (self.num_pages - 1) * self.page_bytes

    def kv_bytes_slotted(self) -> int:
        """K/V bytes a slot-granular pool would statically preallocate for
        the same config — ``max_seq_len`` tokens per slot, bounded by the
        window for ring layouts (the slotted ring cache is window-sized
        too), no page padding."""
        return self.num_slots * self.layout.live_tokens(self.max_seq_len) \
            * (self.page_bytes // self.page_size)
