"""Page-granular KV-cache pool (vLLM-style) for continuous-batching decode.

Where ``SlotKVCachePool`` preallocates ``max_seq_len`` of K/V per slot —
cache memory set by the worst-case sequence — this pool owns one *global*
page pool per layer (``[L, P, page_size, KV, hd]``), a free-page allocator,
and a per-slot page table.  Pages are allocated lazily as a request's
position crosses page boundaries and returned on eviction, so the bytes
*held* track the tokens actually cached, and ``num_pages`` can provision
less than ``max_batch x max_seq_len`` (oversubscription; the engine
preempts on page pressure).

Page 0 is a reserved **trash page**: never allocated, it absorbs the
writes of slots without a request (their page tables are all-zero), of
insert padding, and of masked prefill-bucket tails, so the batched decode
and bucketed prefill keep their fixed shapes without masking any scatter.

**Prefix caching** (``enable_prefix_cache``): every page holds a
*reference count* and, once its request's prefill commits, full
page-aligned prompt blocks are registered in a hash-trie index —
``chain_hash(block_0..i) -> page``.  A new request walks the index with
its own prompt blocks and maps every hit read-only (refcount++): those
positions are never re-prefilled and their pages never duplicated.  The
engine's prefill chunks start past the shared prefix and decode writes at
``pos >= prompt_len``, so a shared page is immutable by construction; the
one exception — a prompt *fully* covered by cached blocks, whose final
token must still run to produce logits — reuses the last block's page
**copy-on-write**: the page is device-copied into a private page, and only
the copy is written.  When a page's refcount drops to zero it is *not*
blanked: it parks in an LRU of reusable cached pages and is reclaimed (and
de-indexed) only when the allocator runs dry — memory pressure evicts
cold prefixes, never live ones.

Device state is three pieces, all fixed-shape (decode compiles once):
  * ``pages``   {"k","v"}: [L, P, ps, KV, hd]  — donated through decode
  * page table  [slots, pages_per_slot] int32  — host-owned (numpy),
    re-uploaded per decode step (tiny; allocation is host-side bookkeeping)
  * ``pos``     [slots] int32                  — tokens cached per slot

Token *t* of a slot lives at page ``table[slot, t // ps]``, offset
``t % ps`` — contiguous, no ring wrap-around, which is why only
``attn_kind == "full"`` families page (see registry.paged_decode_fn).

Eviction hygiene: freed pages go back to the allocator without device-side
blanking — a page is only reachable through a table that points at it, the
next tenant's insert/prefill overwrites every position it reads (the
in-page tail past ``pos`` is masked by length), so stale K/V can never
influence another request.  The aliasing property (no *private* page in
two tables; shared pages only ever read) is tested.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

P_ = jax.sharding.PartitionSpec


def paged_pspecs(pool_structs, *, model_size: int = 1):
    """PartitionSpec tree for the page pool [L, P, ps, KV, hd]: KV-head dim
    -> "model" when divisible (else head_dim); pages replicate — any slot's
    pages live anywhere, so there is no data-axis to shard them over."""

    def rule(leaf):
        spec = [None] * leaf.ndim
        if model_size > 1 and leaf.ndim == 5:
            if leaf.shape[3] % model_size == 0:
                spec[3] = "model"
            elif leaf.shape[4] % model_size == 0:
                spec[4] = "model"
        return P_(*spec)

    return jax.tree.map(rule, pool_structs)


def chain_blocks(tokens: Sequence[int], page_size: int, *,
                 start_block: int = 0, parent: Optional[int] = None):
    """Yield ``(block_idx, block_tokens, parent_hash, chain_hash)`` for each
    full ``page_size``-token block of ``tokens`` from ``start_block`` on.

    ``h_i = hash((h_{i-1}, block_i))``, seeded with ``page_size`` — a
    block's hash commits to the whole prefix, so two chains collide only
    when every leading block matches.  Deterministic within a process (int
    tuples; no PYTHONHASHSEED salt).  ``hash()`` is non-cryptographic, so
    the index additionally stores ``(parent_hash, block_tokens)`` per entry
    and every match is verified against them — a collision degrades to a
    cache miss, never to serving another prompt's K/V.  This generator is
    the ONLY place the chain step lives: lookup (``_plan``), registration
    (``commit_prefix``) and the test helper all walk through it, so the two
    sides of the index cannot drift."""
    h = page_size if parent is None else parent
    for i in range(start_block, len(tokens) // page_size):
        blk = tuple(tokens[i * page_size:(i + 1) * page_size])
        p, h = h, hash((h, blk))
        yield i, blk, p, h


def block_hashes(tokens: Sequence[int], page_size: int) -> List[int]:
    """Chain hashes of the full blocks of ``tokens`` (see chain_blocks)."""
    return [h for _, _, _, h in chain_blocks(tokens, page_size)]


class PagedKVCachePool:
    """Global page pool + refcounted allocator + prefix index + page tables.

    ``blank_page_fn()`` must return ``ModelBundle.init_decode_state(1,
    page_size)`` — its "k"/"v" leaves ([L, 1, ps, KV, hd]) are the
    one-page template the pool tiles ``num_pages`` times.  Prefill states
    handed to ``insert`` must be sized ``cache_len == padded_len``
    (``pages_per_slot * page_size``) so they scatter page-by-page; the
    prefix-cache path (``alloc_prefix`` + the engine's paged prefill)
    bypasses ``insert`` and writes pages in place.
    """

    def __init__(self, num_slots: int, page_size: int, max_seq_len: int,
                 blank_page_fn, *, num_pages: int = 0, mesh=None,
                 model_size: int = 1, enable_prefix_cache: bool = False):
        assert num_slots >= 1 and page_size >= 1
        self.num_slots = num_slots
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.pages_per_slot = -(-max_seq_len // page_size)
        self.padded_len = self.pages_per_slot * page_size
        worst = num_slots * self.pages_per_slot + 1          # +1 trash page
        self.num_pages = num_pages or worst
        if self.num_pages < self.pages_per_slot + 1:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold one request "
                f"(pages_per_slot={self.pages_per_slot} + trash page)")
        self.mesh = mesh
        self.enable_prefix_cache = enable_prefix_cache

        blank = blank_page_fn()
        if not all(k in blank for k in ("k", "v")):
            raise ValueError("paged pool needs a k/v attention cache; "
                             "got leaves " + str(sorted(blank)))
        one = {"k": blank["k"], "v": blank["v"]}             # [L,1,ps,KV,hd]
        P = self.num_pages

        def grow(x):
            return jnp.broadcast_to(
                x[:, 0][:, None], (x.shape[0], P) + x.shape[2:]).copy()

        if mesh is not None:
            structs = jax.eval_shape(lambda t: jax.tree.map(grow, t), one)
            self.pspecs = paged_pspecs(structs, model_size=model_size)
            self.shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), self.pspecs)
            out_sh = {"out_shardings": self.shardings}
        else:
            self.pspecs = None
            self.shardings = None
            out_sh = {}

        def _insert(pages, one_state, ids):
            """Scatter a contiguous prefill cache into pages ``ids``.

            one_state k/v: [L, 1, padded_len, KV, hd]; ids
            [pages_per_slot] int32 — entries past the prompt's pages point
            at the trash page and receive the (blank) tail chunks.
            """
            def put(pool, x):
                xr = x[:, 0].reshape((x.shape[0], self.pages_per_slot,
                                      page_size) + x.shape[3:])
                return pool.at[:, ids].set(xr.astype(pool.dtype))
            return {"k": put(pages["k"], one_state["k"]),
                    "v": put(pages["v"], one_state["v"])}

        def _copy(pages, dst, src):
            """Copy-on-write: duplicate page ``src`` into ``dst`` (every
            layer, k and v) so the new tenant can overwrite its tail."""
            return {"k": pages["k"].at[:, dst].set(pages["k"][:, src]),
                    "v": pages["v"].at[:, dst].set(pages["v"][:, src])}

        self._insert = jax.jit(_insert, donate_argnums=(0,), **out_sh)
        self._copy = jax.jit(_copy, donate_argnums=(0,), **out_sh)
        self.pages = jax.jit(lambda t: jax.tree.map(grow, t), **out_sh)(one)
        if enable_prefix_cache:
            # compile the COW copy now (trash -> trash no-op): the first
            # fully-cached-prompt admission must not stall on a jit trace
            self.pages = self._copy(self.pages, jnp.asarray(0, jnp.int32),
                                    jnp.asarray(0, jnp.int32))

        # bytes of one page across layers and k+v (for telemetry)
        self.page_bytes = sum(
            leaf.nbytes // P for leaf in jax.tree.leaves(self.pages))

        # -- host bookkeeping ---------------------------------------------
        self._free_slots: List[int] = list(range(num_slots))
        self._free_pages: List[int] = list(range(1, P))      # 0 = trash
        self.refcount = np.zeros((P,), np.int32)             # per-page
        self.owner: Dict[int, int] = {}                      # slot -> rid
        self.held: Dict[int, List[int]] = {}                 # slot -> pages
        self.tables = np.zeros((num_slots, self.pages_per_slot), np.int32)
        self.pos = np.zeros((num_slots,), np.int32)
        # prefix index: chain hash -> (page, parent_hash, block_tokens) —
        # the latter two verify every hit (hash collisions degrade to
        # misses); reverse map page -> chain hash; per-slot commit cursor
        # (next block index, parent hash) so chunked commits hash each
        # token once; and the LRU of refcount-0 pages still indexed
        self._index: Dict[int, Tuple[int, int, Tuple[int, ...]]] = {}
        self._block_of_page: Dict[int, int] = {}
        self._commit_cursor: Dict[int, Tuple[int, int]] = {}
        self._cached_lru: "OrderedDict[int, None]" = OrderedDict()
        # one-entry plan memo keyed on index version: the engine's
        # blocked-admission probe and the admission itself (often the same
        # prompt, same cycle) walk the chain hash once between index changes
        self._index_version = 0
        self._plan_memo: Optional[Tuple[int, Tuple[int, ...], tuple]] = None
        self.pages_allocated = 0                             # lifetime counters
        self.pages_freed = 0
        self.peak_pages_held = 0
        self.prefix_hit_pages = 0                            # shared mappings
        self.cow_copies = 0
        self.cached_pages_evicted = 0                        # LRU reclaims

    # -- host bookkeeping --------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.owner)

    @property
    def pages_held(self) -> int:
        """Pages referenced by at least one live slot (shared pages count
        once — that is the point of sharing them)."""
        return int((self.refcount > 0).sum())

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages parked in the prefix-cache LRU (reclaimable)."""
        return len(self._cached_lru)

    def _page_budget(self) -> int:
        """Pages the allocator can hand out right now: content-free pages
        plus reclaimable cached ones."""
        return len(self._free_pages) + len(self._cached_lru)

    def can_admit(self, n_tokens: int) -> bool:
        """Is there a slot and enough free pages for an n_tokens prefill
        (ignoring any prefix sharing — see ``can_admit_prompt``)?"""
        need = -(-n_tokens // self.page_size)
        return bool(self._free_slots) and self._page_budget() >= need

    def can_admit_prompt(self, prompt: Sequence[int]) -> bool:
        """``can_admit`` minus the pages a prefix-cache hit would share."""
        if not self._free_slots:
            return False
        shared, cow_src, _, _ = self._plan(prompt)
        need = -(-len(prompt) // self.page_size) - len(shared)
        return self._alloc_budget(shared, cow_src) >= need

    def _alloc_budget(self, shared: List[int], cow_src: Optional[int]) -> int:
        """Allocatable pages for one admission: the global budget minus LRU
        pages this very admission will map/pin (they stop being
        reclaimable the moment they are re-referenced)."""
        pinned = sum(1 for p in shared + ([cow_src] if cow_src is not None
                                          else []) if p in self._cached_lru)
        return self._page_budget() - pinned

    def _alloc_page(self, slot: int) -> Optional[int]:
        """Hand a private page to ``slot``: content-free pages first, then
        reclaim the least-recently-used cached page (de-indexing it)."""
        if self._free_pages:
            pid = self._free_pages.pop(0)
        elif self._cached_lru:
            pid, _ = self._cached_lru.popitem(last=False)
            h = self._block_of_page.pop(pid)
            entry = self._index.get(h)
            if entry is not None and entry[0] == pid:
                del self._index[h]
            self._index_version += 1
            self.cached_pages_evicted += 1
        else:
            return None
        self.refcount[pid] = 1
        self.held[slot].append(pid)
        self.tables[slot, len(self.held[slot]) - 1] = pid
        self.pages_allocated += 1
        return pid

    # kept name: lazy decode growth and the non-sharing insert path use it
    _take_page = _alloc_page

    def _retain_page(self, pid: int) -> None:
        """refcount++; a 0 -> 1 transition pulls the page out of the LRU and
        counts as an allocation, keeping ``pages_allocated == pages_freed``
        a drain invariant even when cached pages cycle through reuse."""
        if self.refcount[pid] == 0:
            self._cached_lru.pop(pid, None)
            self.pages_allocated += 1
        self.refcount[pid] += 1

    def _map_shared(self, slot: int, pid: int) -> None:
        """Map an indexed page read-only into ``slot``."""
        self._retain_page(pid)
        self.held[slot].append(pid)
        self.tables[slot, len(self.held[slot]) - 1] = pid
        self.prefix_hit_pages += 1

    def _release_page(self, pid: int) -> None:
        """Drop one reference; at zero the page parks in the LRU when its
        content is indexed (reusable prefix) and frees otherwise."""
        self.refcount[pid] -= 1
        assert self.refcount[pid] >= 0, f"page {pid} refcount underflow"
        if self.refcount[pid] == 0:
            self.pages_freed += 1
            if pid in self._block_of_page:
                self._cached_lru[pid] = None        # most-recent end
            else:
                self._free_pages.append(pid)
                self._free_pages.sort()

    # -- prefix matching ---------------------------------------------------

    def _plan(self, prompt: Sequence[int]
              ) -> Tuple[List[int], Optional[int], int, Tuple[int, int]]:
        """(shared_pages, cow_src_page, cached_tokens, commit_seed) for
        ``prompt``; commit_seed = (first block to register, its parent
        chain hash) — ``alloc_prefix`` seeds the slot's commit cursor with
        it, so ``commit_prefix`` never re-hashes blocks the match already
        walked.

        Walks the chain-hash index over the prompt's full blocks, verifying
        each hit's stored (parent_hash, block_tokens) so a ``hash()``
        collision can only miss, never alias another prompt's pages.  A
        match covering the *entire* prompt keeps its last block out of the
        read-only mapping and returns it as ``cow_src`` instead: the final
        prompt token must still run (logits), so that page is duplicated
        copy-on-write and cached_tokens caps at len(prompt) - 1.  The walk
        stops hashing at the first miss — a cold prompt costs one block —
        and the result is memoized until the index next changes, so a probe
        (``can_admit_prompt``) followed by the admission re-plans nothing.
        """
        ps = self.page_size
        if not self.enable_prefix_cache:
            return [], None, 0, (0, ps)
        memo = self._plan_memo
        if memo is not None and memo[0] == self._index_version \
                and memo[1] == tuple(prompt):
            return memo[2]
        matched: List[int] = []
        hashes: List[int] = []
        for _, blk, parent, h in chain_blocks(prompt, ps):
            entry = self._index.get(h)
            if entry is None or entry[1] != parent or entry[2] != blk:
                break
            matched.append(entry[0])
            hashes.append(h)
        if not matched:
            out = [], None, 0, (0, ps)
        elif len(matched) * ps == len(prompt):
            # the shared read-only blocks end one short of the match; the
            # COW block itself is already indexed, so commits resume there
            seed = (len(matched) - 1,
                    hashes[-2] if len(hashes) > 1 else ps)
            out = matched[:-1], matched[-1], len(prompt) - 1, seed
        else:
            out = matched, None, len(matched) * ps, \
                (len(matched), hashes[-1])
        self._plan_memo = (self._index_version, tuple(prompt), out)
        return out

    # -- engine API --------------------------------------------------------

    def alloc_prefix(self, rid: int, prompt: Sequence[int]
                     ) -> Optional[Tuple[int, int]]:
        """Allocate a slot for ``prompt``, mapping the longest cached
        page-aligned prefix read-only and private pages for the rest.

        Returns (slot, cached_tokens) — the engine prefills only positions
        ``cached_tokens..len(prompt)-1`` — or None when slots or pages run
        short (caller re-queues the request).  ``pos`` is set to the full
        prompt length up front; the engine masks the slot out of decode
        until its chunked prefill completes.
        """
        plen = len(prompt)
        shared, cow_src, cached, seed = self._plan(prompt)
        total = -(-plen // self.page_size)
        if not self._free_slots or \
                self._alloc_budget(shared, cow_src) < total - len(shared):
            return None
        slot = self._free_slots.pop(0)
        assert slot not in self.owner, f"slot {slot} double-assigned"
        self.owner[slot] = rid
        self.held[slot] = []
        self.tables[slot] = 0
        # the commit cursor resumes after the matched prefix — blocks the
        # plan walked are never re-hashed by commit_prefix
        self._commit_cursor[slot] = seed
        for pid in shared:
            self._map_shared(slot, pid)
        if cow_src is not None:
            # pin the source so this alloc's own page grabs cannot reclaim
            # it out of the LRU before the device copy lands
            self._retain_page(cow_src)
            dst = self._alloc_page(slot)
            self.pages = self._copy(self.pages, jnp.asarray(dst, jnp.int32),
                                    jnp.asarray(cow_src, jnp.int32))
            self.cow_copies += 1
            self._release_page(cow_src)
        for _ in range(total - len(self.held[slot])):
            self._alloc_page(slot)
        self.pos[slot] = plen
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return slot, cached

    def commit_prefix(self, slot: int, prompt: Sequence[int]) -> None:
        """Register the slot's now-written full prompt blocks in the index
        (first writer wins; later identical blocks stay private and simply
        free on eviction).  Chunked prefill calls this after every chunk
        with a growing prefix; the per-slot cursor resumes the chain hash
        where the last call stopped, so each token is hashed exactly once
        per admission."""
        if not self.enable_prefix_cache:
            return
        ps = self.page_size
        start, parent = self._commit_cursor.get(slot, (0, ps))
        cursor = (start, parent)
        for i, blk, p, h in chain_blocks(prompt, ps, start_block=start,
                                         parent=parent):
            if h not in self._index:
                pid = self.held[slot][i]
                self._index[h] = (pid, p, blk)
                self._block_of_page[pid] = h
                self._index_version += 1
            cursor = (i + 1, h)
        self._commit_cursor[slot] = cursor

    def insert(self, rid: int, one_state, n_tokens: int) -> Optional[int]:
        """Place a prefilled cache (cache_len == padded_len) into a free
        slot, allocating ceil(n_tokens / page_size) pages.  None when slots
        or pages are exhausted (caller re-queues the request).  This is the
        non-sharing path: the scatter writes every table entry, so it must
        never be handed pages another slot can read."""
        if not self.can_admit(n_tokens):
            return None
        slot = self._free_slots.pop(0)
        assert slot not in self.owner, f"slot {slot} double-assigned"
        self.owner[slot] = rid
        self.held[slot] = []
        self.tables[slot] = 0
        for _ in range(-(-n_tokens // self.page_size)):
            self._take_page(slot)
        self.pos[slot] = n_tokens
        one_kv = {"k": one_state["k"], "v": one_state["v"]}
        self.pages = self._insert(self.pages, one_kv,
                                  jnp.asarray(self.tables[slot]))
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return slot

    def evict(self, slot: int) -> int:
        """Free a slot: every mapped page drops one reference; pages whose
        content is indexed park in the prefix LRU instead of freeing (no
        device blanking either way — see module docstring on hygiene)."""
        rid = self.owner.pop(slot)
        for pid in self.held.pop(slot):
            self._release_page(pid)
        self._commit_cursor.pop(slot, None)
        self.tables[slot] = 0
        self.pos[slot] = 0
        self._free_slots.append(slot)
        self._free_slots.sort()
        return rid

    def clear_prefix_cache(self) -> None:
        """Invalidate the prefix index: every refcount-0 cached page returns
        to the free list and no future request can map a previously cached
        block.  Live slots keep serving off their mapped pages — but those
        pages are de-indexed too, so they free (rather than park) on
        eviction.  Call when cached K/V stops being valid (weight updates)
        or to measure cold-start behaviour on a warm engine."""
        self._free_pages.extend(self._cached_lru)
        self._free_pages.sort()
        self._cached_lru.clear()
        self._index.clear()
        self._block_of_page.clear()
        self._index_version += 1

    def ensure_decode_capacity(self, skip=()) -> List[int]:
        """Lazily allocate so every active slot can write position ``pos``
        (the next decode token).  Returns the slots that could not be
        extended — the engine preempts to relieve the pressure.  Slots in
        ``skip`` (still prefilling: pages preallocated, no decode write
        coming) are left alone."""
        starved = []
        for slot in self.active_slots:
            if slot in skip:
                continue
            need = int(self.pos[slot]) // self.page_size + 1
            while len(self.held[slot]) < need:
                if self._take_page(slot) is None:
                    starved.append(slot)
                    break
        self.peak_pages_held = max(self.peak_pages_held, self.pages_held)
        return starved

    def decode_view(self, mask_slots=()) -> Tuple[jax.Array, jax.Array]:
        """(page_table, pos) device operands for one decode step.  Slots in
        ``mask_slots`` (mid-prefill) present an all-trash table and pos 0,
        so the fixed-shape decode can run while they fill."""
        if mask_slots:
            tables = self.tables.copy()
            pos = self.pos.copy()
            for s in mask_slots:
                tables[s] = 0
                pos[s] = 0
            return jnp.asarray(tables), jnp.asarray(pos)
        return jnp.asarray(self.tables), jnp.asarray(self.pos)

    def advance(self, skip=()) -> None:
        """One decode step happened: every decoding slot cached one token."""
        for slot in self.owner:
            if slot not in skip:
                self.pos[slot] += 1

    # -- telemetry ---------------------------------------------------------

    def kv_bytes_held(self) -> int:
        return self.pages_held * self.page_bytes

    def kv_bytes_capacity(self) -> int:
        return (self.num_pages - 1) * self.page_bytes

    def kv_bytes_slotted(self) -> int:
        """K/V bytes a slot-granular pool would statically preallocate for
        the same config (max_seq_len tokens per slot, no page padding)."""
        return self.num_slots * self.max_seq_len * (self.page_bytes
                                                    // self.page_size)
