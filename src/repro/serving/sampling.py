"""Per-request sampling: params, packing, and the fused device sampler.

``SamplingParams`` travels with a request from ``engine.submit()`` /
``Session.generate`` down into the engine's fused decode scan.  The
sampler is **counter-based**: the token at absolute sequence index
``i`` (0-based over prompt + generated) is drawn with
``fold_in(PRNGKey(seed), i)``, so a request's tokens are a pure
function of (prompt, params) — independent of batch composition, slot
assignment, paged vs slotted layout, mesh, warm vs cold caches, and
pipeline depth.  No RNG state is carried between steps and no host
sync is needed to advance it.

``temperature == 0`` lowers to argmax inside the same sampler, so
greedy requests in a mixed batch emit exactly the dedicated greedy
scan's tokens (the engine still dispatches the argmax-only scan when
*every* row is greedy, keeping the zero-dispatch next-token memo and
compile behavior of greedy traffic untouched).

This module is import-light (numpy/jax only) so ``scheduler.Request``
can carry a ``SamplingParams`` without layering cycles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SamplingParams", "GREEDY", "pack_params", "sample_tokens",
           "PACKED_WIDTH"]

# Packed on-device layout, one int32 row per slot:
#   [bitcast(f32 temperature), bitcast(f32 top_p), top_k, seed]
PACKED_WIDTH = 4


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters, validated at construction.

    temperature: 0 disables sampling (greedy argmax); > 0 scales logits.
    top_k: keep the k highest logits (0 disables the filter).
    top_p: nucleus filter — keep the smallest prob mass >= top_p
        (1.0 disables the filter).
    seed: per-request PRNG seed; the only source of randomness.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        t = self.temperature
        if not isinstance(t, (int, float)) or isinstance(t, bool) or t < 0:
            raise ValueError(
                f"temperature={t!r} must be a float >= 0")
        k = self.top_k
        if not isinstance(k, (int, np.integer)) or isinstance(k, bool) or k < 0:
            raise ValueError(f"top_k={k!r} must be an int >= 0")
        p = self.top_p
        if not isinstance(p, (int, float)) or isinstance(p, bool) \
                or not 0.0 < p <= 1.0:
            raise ValueError(f"top_p={p!r} must be a float in (0, 1]")
        s = self.seed
        if not isinstance(s, (int, np.integer)) or isinstance(s, bool) \
                or not 0 <= s < 2 ** 31:
            raise ValueError(f"seed={s!r} must be an int in [0, 2**31)")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def pack_params(p: SamplingParams) -> np.ndarray:
    """One [PACKED_WIDTH] int32 row; floats travel bit-exact via bitcast."""
    return np.array([
        np.float32(p.temperature).view(np.int32),
        np.float32(p.top_p).view(np.int32),
        np.int32(p.top_k),
        np.int32(p.seed),
    ], dtype=np.int32)


def sample_tokens(logits, packed, idx):
    """Sample one token per row.  Pure function of (logits, packed, idx).

    logits [S, V] — next-token logits per row.
    packed [S, PACKED_WIDTH] int32 — per-row packed SamplingParams.
    idx    [S] int32 — absolute sequence index of the token being drawn
        (counter folded into the row's seed).

    Returns [S] int32 tokens.  Rows with temperature == 0 take the
    argmax path (bit-identical to the greedy scan); the filters follow
    the usual order: temperature scale -> top-k -> top-p -> categorical.
    The filter+draw pipeline (two vocab sorts, softmax, per-row threefry)
    is several times the cost of the forward it follows on small models,
    so it sits behind a ``lax.cond``: an all-greedy call — every
    speculative verify of greedy traffic — pays for the argmax only.
    """
    logits = logits.astype(jnp.float32)
    temp = jax.lax.bitcast_convert_type(packed[:, 0], jnp.float32)
    top_p = jax.lax.bitcast_convert_type(packed[:, 1], jnp.float32)
    top_k = packed[:, 2]
    seed = packed[:, 3]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def sampled_rows(_):
        V = logits.shape[-1]
        scaled = logits / jnp.maximum(temp, 1e-6)[:, None]
        # top-k: threshold at the k-th largest value (ties past k survive
        # — deterministic either way, which is all reproducibility needs)
        desc = jnp.sort(scaled, axis=-1)[:, ::-1]
        k = jnp.clip(top_k, 0, V)
        kth = jnp.take_along_axis(desc, jnp.maximum(k - 1, 0)[:, None],
                                  axis=-1)
        scaled = jnp.where((scaled >= kth) | (k <= 0)[:, None],
                           scaled, -jnp.inf)
        # top-p over the post-k distribution: keep the smallest prefix of
        # the sorted probs whose mass reaches top_p (the first always stays)
        probs = jax.nn.softmax(scaled, axis=-1)
        p_desc = jnp.sort(probs, axis=-1)[:, ::-1]
        cum = jnp.cumsum(p_desc, axis=-1)
        kept = (cum - p_desc) < top_p[:, None]
        cutoff = jnp.min(jnp.where(kept, p_desc, jnp.inf), axis=-1)
        keep = (probs >= cutoff[:, None]) | (top_p >= 1.0)[:, None]
        scaled = jnp.where(keep, scaled, -jnp.inf)

        def draw(seed_i, idx_i, row):
            key = jax.random.fold_in(jax.random.PRNGKey(seed_i), idx_i)
            return jax.random.categorical(key, row)

        sampled = jax.vmap(draw)(seed, idx.astype(jnp.int32), scaled)
        return jnp.where(temp > 0.0, sampled.astype(jnp.int32), greedy)

    return jax.lax.cond(jnp.any(temp > 0.0), sampled_rows,
                        lambda _: greedy, operand=None)
