"""Serving telemetry: TTFT, inter-token latency, queue depth, tokens/sec.

``ServingMetrics`` is a plain host-side accumulator — the engine calls the
``record_*`` hooks from its event loop; nothing here touches jax.  The clock
is injectable so tests can drive deterministic timelines.

``summary()`` is the export surface: a flat dict (JSON-friendly) consumed by
``launch/serve.py`` (pretty print) and ``benchmarks/serving.py``
(BENCH_serving.json trajectory).  When the engine runs traced
(``ServeConfig(trace=True)``) the attached ``repro.obs.Tracer``'s per-phase
seconds fold into the same dict — plan / prefill / decode / other wall
time, and the prefill-vs-decode throughput split those times enable.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Union

from repro.obs import NULL_TRACER, phase_snapshot
from repro.obs.export import DECODE_TIME_S, PREFILL_TIME_S


def percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile without numpy (metrics must stay import-light).

    Standard ceil-based nearest rank: the smallest value with at least
    ``p%`` of the sample at or below it.  (An earlier version rounded the
    rank with Python's banker's rounding — ``round(0.5) == 0`` — biasing
    p50/p99 low on small samples.)
    """
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(math.ceil((p / 100.0) * len(s)), 1) - 1
    return s[min(k, len(s) - 1)]


class ServingMetrics:
    """Per-request latency + engine throughput counters.

    Timeline per request: submit -> first_token (TTFT, covers queueing +
    prefill) -> token* (inter-token latency) -> completion.

    ``tracer`` (a ``repro.obs.Tracer`` / ``NULL_TRACER``) is attached by
    the engine; ``summary()`` folds its per-phase seconds in.  The tracer
    is engine-owned and survives ``reset()`` — reset it separately when a
    measured window must start clean.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 tracer=None):
        self._clock = clock or time.monotonic
        self.tracer = tracer
        self.reset()

    def reset(self) -> None:
        """Zero every counter (benchmarks reuse warm engines).  The
        attached tracer is NOT reset — it is engine-owned state."""
        self._submit_t: Dict[int, float] = {}
        self._last_token_t: Dict[int, float] = {}
        self.ttft: List[float] = []
        self.itl: List[float] = []                 # inter-token latencies
        self.queue_depth: List[int] = []           # sampled once per cycle
        self.kv_bytes: List[int] = []              # sampled once per cycle
        self.kv_bytes_slotted = 0                  # slot-pool equivalent
        self.preemptions = 0
        self.rejected = 0
        self.completed = 0
        self.tokens_out = 0
        self.decode_tokens = 0                     # emitted by decode steps
        self.drafted_tokens = 0                    # speculative proposals
        self.accepted_tokens = 0                   # proposals that matched
        self.prefill_tokens = 0
        self.prefix_hit_tokens = 0                 # served from cached pages
        self.prefill_compiles = 0                  # distinct prefill traces
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None

    def now(self) -> float:
        return self._clock()

    # -- engine hooks ------------------------------------------------------

    def record_submit(self, rid: int) -> None:
        t = self.now()
        if self._t_start is None:
            self._t_start = t
        self._submit_t[rid] = t

    def record_reject(self) -> None:
        self.rejected += 1

    def record_prefill(self, n_prompt_tokens: int) -> None:
        """Prompt tokens actually *run* through prefill (bucket padding and
        prefix-cache hits excluded — this is the FLOPs-proportional count)."""
        self.prefill_tokens += n_prompt_tokens

    def record_decode_token(self) -> None:
        """A token produced by a batched *decode* step (as opposed to the
        token a prefill's final logits emit) — the numerator of
        ``decode_tokens_per_sec``."""
        self.decode_tokens += 1

    def record_spec(self, drafted: int, accepted: int) -> None:
        """One speculative verify retired: ``drafted`` tokens were
        proposed, ``accepted`` of them matched the engine's own output
        (``accept_rate = accepted / drafted`` in the summary).  The
        accepted tokens themselves also flow through
        ``record_decode_token`` — they are real output tokens."""
        self.drafted_tokens += drafted
        self.accepted_tokens += accepted

    def record_prefix_hit(self, n_tokens: int) -> None:
        """Prompt tokens served from shared cached pages instead of being
        re-prefilled (the prefix cache's compute saving)."""
        self.prefix_hit_tokens += n_tokens

    def record_prefill_compile(self) -> None:
        """The engine traced a new prefill shape (one XLA compile).  With
        power-of-two bucketing this stays O(log max_seq_len); unbounded
        growth here is the per-prompt-length jit explosion."""
        self.prefill_compiles += 1

    def record_first_token(self, rid: int) -> None:
        t = self.now()
        if rid in self._submit_t:
            self.ttft.append(t - self._submit_t[rid])
        self._last_token_t[rid] = t
        self.tokens_out += 1
        self._t_end = t

    def record_token(self, rid: int) -> None:
        t = self.now()
        last = self._last_token_t.get(rid)
        if last is not None:
            self.itl.append(t - last)
        self._last_token_t[rid] = t
        self.tokens_out += 1
        self._t_end = t

    def record_completion(self, rid: int) -> None:
        self.completed += 1
        self._t_end = self.now()
        self._submit_t.pop(rid, None)
        self._last_token_t.pop(rid, None)

    def record_preemption(self, rid: Optional[int] = None) -> None:
        """A running request was evicted.  Dropping its last-token timestamp
        keeps eviction + re-queue + re-prefill time *out* of inter-token
        latency: the first token after resume sets a fresh baseline instead
        of recording the whole preemption gap as one giant ITL sample."""
        self.preemptions += 1
        if rid is not None:
            self._last_token_t.pop(rid, None)

    def drop_itl_baseline(self, rid: int) -> None:
        """Forget a request's last-token timestamp without counting a
        preemption.  The pipelined engine's retire phase calls this after
        emitting a preempted victim's in-flight tokens — those emissions
        re-seed the baseline ``record_preemption`` had just dropped, and
        without this the requeue -> resume gap would land in ITL as one
        giant sample."""
        self._last_token_t.pop(rid, None)

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth.append(depth)

    def sample_kv_bytes(self, held: int, slotted_equiv: int) -> None:
        """KV bytes currently held by the pool vs what a slot-granular pool
        would statically preallocate.  Sampled once per admission cycle and
        (paged) after each decode-step page growth, so the peak is the true
        high-water mark, not the per-cycle snapshot."""
        self.kv_bytes.append(held)
        self.kv_bytes_slotted = slotted_equiv

    # -- export ------------------------------------------------------------

    def elapsed(self) -> float:
        """Measurement window in seconds: first ``record_submit`` to the
        last token/completion event (submit -> last-token, NOT process
        lifetime — queueing is inside the window, engine idle time after
        the last completion is not).  0.0 when nothing was ever admitted
        (e.g. a run where every request was rejected): the throughput
        fields then report honest zeros while ``rejected`` still counts
        the shed load."""
        if self._t_start is None or self._t_end is None:
            return 0.0
        return max(self._t_end - self._t_start, 0.0)

    def summary(self) -> Dict[str, Union[int, float]]:
        """Flat JSON-friendly export (int counters + float gauges; an
        earlier annotation claimed all-float).  Rate fields divide by
        ``elapsed()`` / traced phase seconds and report 0.0 whenever the
        denominator is 0 — a rejected-everything run or an untraced engine
        yields honest zeros, never a ZeroDivisionError.

        ``decode_tokens_per_sec`` / ``prefill_tokens_per_sec`` split the
        combined ``tokens_per_sec`` (kept for BENCH comparability) by the
        tracer's accumulated device-phase time: decode tokens over decode
        kernel seconds, prefill tokens *run* (prefix hits excluded) over
        prefill kernel seconds.  Both are 0.0 with tracing off — per-phase
        time does not exist untraced.
        """
        dt = self.elapsed()
        prompt_tokens = self.prefill_tokens + self.prefix_hit_tokens
        # phase keys come from repro.obs.export's named constants (shared
        # with the bench schema gate), including ``host_overhead_frac`` —
        # the async-pipeline acceptance number
        phases = phase_snapshot(self.tracer if self.tracer is not None
                                else NULL_TRACER)
        dec_t, pre_t = phases[DECODE_TIME_S], phases[PREFILL_TIME_S]
        return {
            "completed": self.completed,
            "tokens_out": self.tokens_out,
            "decode_tokens": self.decode_tokens,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "accept_rate": (self.accepted_tokens / self.drafted_tokens
                            if self.drafted_tokens else 0.0),
            "prefill_tokens": self.prefill_tokens,
            "prefix_hit_rate": (self.prefix_hit_tokens / prompt_tokens
                                if prompt_tokens else 0.0),
            "prefill_tokens_saved": self.prefix_hit_tokens,
            "compile_count": self.prefill_compiles,
            "elapsed_s": dt,
            "tokens_per_sec": (self.tokens_out / dt) if dt > 0 else 0.0,
            "decode_tokens_per_sec": (self.decode_tokens / dec_t
                                      if dec_t > 0 else 0.0),
            "prefill_tokens_per_sec": (self.prefill_tokens / pre_t
                                       if pre_t > 0 else 0.0),
            **phases,
            "ttft_mean_s": sum(self.ttft) / len(self.ttft) if self.ttft else 0.0,
            "ttft_p50_s": percentile(self.ttft, 50),
            "ttft_p99_s": percentile(self.ttft, 99),
            "itl_p50_s": percentile(self.itl, 50),
            "itl_p99_s": percentile(self.itl, 99),
            "queue_depth_max": max(self.queue_depth, default=0),
            "queue_depth_mean": (sum(self.queue_depth) / len(self.queue_depth)
                                 if self.queue_depth else 0.0),
            "preemptions": self.preemptions,
            "rejected": self.rejected,
            "kv_bytes_peak": max(self.kv_bytes, default=0),
            "kv_bytes_mean": (sum(self.kv_bytes) / len(self.kv_bytes)
                              if self.kv_bytes else 0.0),
            "kv_bytes_slotted": self.kv_bytes_slotted,
        }
