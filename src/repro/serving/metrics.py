"""Serving telemetry: TTFT, inter-token latency, queue depth, tokens/sec.

``ServingMetrics`` is a plain host-side accumulator — the engine calls the
``record_*`` hooks from its event loop; nothing here touches jax.  The clock
is injectable so tests can drive deterministic timelines.

``summary()`` is the export surface: a flat dict (JSON-friendly) consumed by
``launch/serve.py`` (pretty print) and ``benchmarks/serving.py``
(BENCH_serving.json trajectory).
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional


def percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile without numpy (metrics must stay import-light).

    Standard ceil-based nearest rank: the smallest value with at least
    ``p%`` of the sample at or below it.  (An earlier version rounded the
    rank with Python's banker's rounding — ``round(0.5) == 0`` — biasing
    p50/p99 low on small samples.)
    """
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(math.ceil((p / 100.0) * len(s)), 1) - 1
    return s[min(k, len(s) - 1)]


class ServingMetrics:
    """Per-request latency + engine throughput counters.

    Timeline per request: submit -> first_token (TTFT, covers queueing +
    prefill) -> token* (inter-token latency) -> completion.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._clock = clock or time.monotonic
        self.reset()

    def reset(self) -> None:
        """Zero every counter (benchmarks reuse warm engines)."""
        self._submit_t: Dict[int, float] = {}
        self._last_token_t: Dict[int, float] = {}
        self.ttft: List[float] = []
        self.itl: List[float] = []                 # inter-token latencies
        self.queue_depth: List[int] = []           # sampled once per cycle
        self.kv_bytes: List[int] = []              # sampled once per cycle
        self.kv_bytes_slotted = 0                  # slot-pool equivalent
        self.preemptions = 0
        self.rejected = 0
        self.completed = 0
        self.tokens_out = 0
        self.prefill_tokens = 0
        self.prefix_hit_tokens = 0                 # served from cached pages
        self.prefill_compiles = 0                  # distinct prefill traces
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None

    def now(self) -> float:
        return self._clock()

    # -- engine hooks ------------------------------------------------------

    def record_submit(self, rid: int) -> None:
        t = self.now()
        if self._t_start is None:
            self._t_start = t
        self._submit_t[rid] = t

    def record_reject(self) -> None:
        self.rejected += 1

    def record_prefill(self, n_prompt_tokens: int) -> None:
        """Prompt tokens actually *run* through prefill (bucket padding and
        prefix-cache hits excluded — this is the FLOPs-proportional count)."""
        self.prefill_tokens += n_prompt_tokens

    def record_prefix_hit(self, n_tokens: int) -> None:
        """Prompt tokens served from shared cached pages instead of being
        re-prefilled (the prefix cache's compute saving)."""
        self.prefix_hit_tokens += n_tokens

    def record_prefill_compile(self) -> None:
        """The engine traced a new prefill shape (one XLA compile).  With
        power-of-two bucketing this stays O(log max_seq_len); unbounded
        growth here is the per-prompt-length jit explosion."""
        self.prefill_compiles += 1

    def record_first_token(self, rid: int) -> None:
        t = self.now()
        if rid in self._submit_t:
            self.ttft.append(t - self._submit_t[rid])
        self._last_token_t[rid] = t
        self.tokens_out += 1
        self._t_end = t

    def record_token(self, rid: int) -> None:
        t = self.now()
        last = self._last_token_t.get(rid)
        if last is not None:
            self.itl.append(t - last)
        self._last_token_t[rid] = t
        self.tokens_out += 1
        self._t_end = t

    def record_completion(self, rid: int) -> None:
        self.completed += 1
        self._t_end = self.now()
        self._submit_t.pop(rid, None)
        self._last_token_t.pop(rid, None)

    def record_preemption(self, rid: Optional[int] = None) -> None:
        """A running request was evicted.  Dropping its last-token timestamp
        keeps eviction + re-queue + re-prefill time *out* of inter-token
        latency: the first token after resume sets a fresh baseline instead
        of recording the whole preemption gap as one giant ITL sample."""
        self.preemptions += 1
        if rid is not None:
            self._last_token_t.pop(rid, None)

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth.append(depth)

    def sample_kv_bytes(self, held: int, slotted_equiv: int) -> None:
        """KV bytes currently held by the pool vs what a slot-granular pool
        would statically preallocate.  Sampled once per admission cycle and
        (paged) after each decode-step page growth, so the peak is the true
        high-water mark, not the per-cycle snapshot."""
        self.kv_bytes.append(held)
        self.kv_bytes_slotted = slotted_equiv

    # -- export ------------------------------------------------------------

    def elapsed(self) -> float:
        if self._t_start is None or self._t_end is None:
            return 0.0
        return max(self._t_end - self._t_start, 0.0)

    def summary(self) -> Dict[str, float]:
        dt = self.elapsed()
        prompt_tokens = self.prefill_tokens + self.prefix_hit_tokens
        return {
            "completed": self.completed,
            "tokens_out": self.tokens_out,
            "prefill_tokens": self.prefill_tokens,
            "prefix_hit_rate": (self.prefix_hit_tokens / prompt_tokens
                                if prompt_tokens else 0.0),
            "prefill_tokens_saved": self.prefix_hit_tokens,
            "compile_count": self.prefill_compiles,
            "elapsed_s": dt,
            "tokens_per_sec": (self.tokens_out / dt) if dt > 0 else 0.0,
            "ttft_mean_s": sum(self.ttft) / len(self.ttft) if self.ttft else 0.0,
            "ttft_p50_s": percentile(self.ttft, 50),
            "ttft_p99_s": percentile(self.ttft, 99),
            "itl_p50_s": percentile(self.itl, 50),
            "itl_p99_s": percentile(self.itl, 99),
            "queue_depth_max": max(self.queue_depth, default=0),
            "queue_depth_mean": (sum(self.queue_depth) / len(self.queue_depth)
                                 if self.queue_depth else 0.0),
            "preemptions": self.preemptions,
            "rejected": self.rejected,
            "kv_bytes_peak": max(self.kv_bytes, default=0),
            "kv_bytes_mean": (sum(self.kv_bytes) / len(self.kv_bytes)
                              if self.kv_bytes else 0.0),
            "kv_bytes_slotted": self.kv_bytes_slotted,
        }
