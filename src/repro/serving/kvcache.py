"""Slotted, preallocated KV-cache pool for continuous-batching decode.

The pool holds one decode-state pytree whose leaves are stacked over a
leading **slot** axis: ``[slots, <single-sequence decode state>]``, where the
single-sequence state is exactly what ``ModelBundle.serve_prefill_fn``
returns for a batch-of-1 prompt (e.g. GQA ring caches ``k/v
[L, 1, Lc, KV, hd]`` with per-layer ``pos``/``index``).  Because every slot
carries its *own* position/index leaves, slots decode at ragged sequence
positions — the property plain batched decode state (shared ``pos``) lacks,
and the reason the old serve loop had to re-prefill whole batches.

All device ops compile exactly once:
  * ``insert``  — scatter a prefilled state into slot *i* (traced index)
  * ``read``    — gather slot *i* back out (tests / debugging)
  * ``reset``   — restore slot *i* to the blank state (eviction hygiene)

Since the KV-layout seam (``repro.serving.layouts``) brought MLA and
windowed attention onto the paged pool, this pool serves two roles only:
the *recurrent* families' home (RG-LRU / RWKV — O(1) state per slot has
no layout, nothing to page) and the forced ``kv_layout="slotted"``
baseline every paged layout is token-identity-tested against.

The slotted path participates in prefill *bucketing* only (engine-side:
prompts padded to power-of-two buckets with masked tails bound the jit
cache; the inserted state's shape is keyed by ``cache_len`` alone, so
bucketing never changes what lands here).  Prefix-cache page sharing and
chunked prefill are paged-pool features — a slot-granular state has no
page indirection to share or to fill incrementally.

Free-slot bookkeeping is host-side; the engine maps slot -> request.

Mesh transparency: ``pool_pspecs`` derives a PartitionSpec tree for the pool
(slot axis -> data axes, head/feature dims -> model axis when divisible), so
the engine serves data-parallel across slots and tensor-parallel within a
decode step from config alone — same name-matched rule style as
``launch/sharding.py`` (whose specs cover the *unslotted* serve states).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Sharding rules (pooled leaves = single-seq leaves + leading slot axis)
# ---------------------------------------------------------------------------

def pool_pspecs(pool_structs, *, dp_axes: Tuple[str, ...] = (),
                dp_total: int = 1, model_size: int = 1):
    """PartitionSpec tree for a slot pool.

    slot axis (dim 0) -> dp axes when the slot count divides them;
    k/v KV-head (else head_dim), MLA rank, and wkv head dims -> "model"
    when divisible.  ``pos``/``index`` leaves replicate except for the slot
    axis itself.
    """

    def _model(dim: int):
        return "model" if model_size > 1 and dim % model_size == 0 else None

    def rule(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        nd = leaf.ndim
        spec = [None] * nd
        slots = leaf.shape[0] if nd else 1
        if dp_axes and dp_total > 1 and slots % dp_total == 0:
            spec[0] = tuple(dp_axes)
        if name in ("k", "v") and nd == 6:          # [slots,L,1,Lc,KV,hd]
            spec[4] = _model(leaf.shape[4])
            if spec[4] is None:
                spec[5] = _model(leaf.shape[5])
        elif name in ("ckv", "krope") and nd == 5:  # [slots,L,1,Lc,R]
            spec[4] = _model(leaf.shape[4])
        elif name == "s" and nd == 6:               # [slots,L,1,H,hd,hd]
            spec[3] = _model(leaf.shape[3])
        elif name == "h" and nd == 4:               # [slots,n,1,W]
            spec[3] = _model(leaf.shape[3])
        elif name == "conv" and nd == 5:            # [slots,n,1,cw-1,W]
            spec[4] = _model(leaf.shape[4])
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, pool_structs)


# ---------------------------------------------------------------------------
# The pool
# ---------------------------------------------------------------------------

class SlotKVCachePool:
    """Fixed-shape pool of ``num_slots`` per-sequence decode states.

    ``blank_fn()`` must return the single-sequence (batch=1) decode state a
    fresh slot holds — ``ModelBundle.init_decode_state(1, cache_len)``.  The
    pool allocates once; insertion/eviction are per-slot scatters, never a
    batch rebuild, so the batched-decode shape the engine compiles against
    is constant for the lifetime of the process.
    """

    def __init__(self, num_slots: int, blank_fn: Callable[[], object],
                 mesh=None, dp_axes: Tuple[str, ...] = (),
                 dp_total: int = 1, model_size: int = 1):
        assert num_slots >= 1
        self.num_slots = num_slots
        self.mesh = mesh
        blank = blank_fn()
        pool_structs = jax.eval_shape(
            lambda b: jax.tree.map(
                lambda x: jnp.broadcast_to(x, (num_slots,) + x.shape), b),
            blank)
        if mesh is not None:
            self.pspecs = pool_pspecs(pool_structs, dp_axes=dp_axes,
                                      dp_total=dp_total, model_size=model_size)
            self.shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), self.pspecs)
        else:
            self.pspecs = None
            self.shardings = None

        def _stack(b):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (num_slots,) + x.shape).copy(), b)

        def _insert(pool, one, slot):
            return jax.tree.map(lambda p, o: p.at[slot].set(o), pool, one)

        def _read(pool, slot):
            return jax.tree.map(lambda p: p[slot], pool)

        out_sh = {"out_shardings": self.shardings} if mesh is not None else {}
        self._blank = blank
        self._insert = jax.jit(_insert, donate_argnums=(0,), **out_sh)
        self._read = jax.jit(_read)
        self.state = jax.jit(_stack, **out_sh)(blank)
        self._free: List[int] = list(range(num_slots))
        self.owner: Dict[int, int] = {}      # slot -> request id

    # -- host bookkeeping --------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.owner)

    def alloc(self, rid: int) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop(0)
        assert slot not in self.owner, f"slot {slot} double-assigned"
        self.owner[slot] = rid
        return slot

    # -- device ops (each compiled once) -----------------------------------

    def insert_at(self, slot: int, one_state) -> None:
        """Scatter a prefilled single-sequence state into an already
        ``alloc``-ed slot — the device half of admission.  A pipelined
        engine allocates in its plan phase (host decision) and dispatches
        this at submit; the one-shot ``insert`` composes both."""
        self.state = self._insert(self.state, one_state,
                                  jnp.asarray(slot, jnp.int32))

    def insert(self, rid: int, one_state) -> Optional[int]:
        """Place a prefilled single-sequence state into a free slot."""
        slot = self.alloc(rid)
        if slot is not None:
            self.insert_at(slot, one_state)
        return slot

    def evict(self, slot: int):
        """Free a slot and blank its state (stale K/V never leaks into a
        later tenant even transiently)."""
        rid = self.owner.pop(slot)
        self.state = self._insert(self.state, self._blank,
                                  jnp.asarray(slot, jnp.int32))
        self._free.append(slot)
        self._free.sort()
        return rid

    def read(self, slot: int):
        return self._read(self.state, jnp.asarray(slot, jnp.int32))

    # -- telemetry (parity with PagedKVCachePool) --------------------------

    def kv_bytes_held(self) -> int:
        """Slot-granular pools hold their full preallocation for the whole
        process lifetime — that constant is exactly what paging lifts.
        Counts cache payload only (pos/index bookkeeping excluded) so the
        number is directly comparable to the paged pool's page bytes."""
        total = 0

        def add(path, leaf):
            nonlocal total
            name = None
            for p in reversed(path):
                if isinstance(p, jax.tree_util.DictKey):
                    name = p.key
                    break
            if name not in ("pos", "index"):
                total += leaf.nbytes
            return leaf

        jax.tree_util.tree_map_with_path(add, self.state)
        return total

    def kv_bytes_slotted(self) -> int:
        return self.kv_bytes_held()
