"""Continuous-batching serving engine with a pipelined submit/retire cycle.

The user supplies a model config (whose registry bundle declares the
``ServeContract`` / ``PagedServeContract`` / ``PagedPrefillContract`` decode
paths — the engine dispatches on ``bundle.capabilities()``, never on
``is None`` probes); the engine supplies everything the paper's transparency
principle says the runtime should own: request admission, slot-level
KV-cache management, prefix-cache page sharing, prefill/decode interleaving,
and mesh sharding.  A sequential "one request at a time" mental model in,
heavy traffic out.  User scripts reach this through ``repro.api``'s
``Session.serve`` / ``Session.generate``.

Pipelined event loop (one ``step()`` = one cycle, three phases):

  plan    — pure host decisions, nothing blocks on the device: priority
            preemption, admission (prefix-page mapping / slot+page
            allocation), chunk sizing + page preparation, per-slot decode
            budgets (``limits``), and lazy page growth for the whole
            decode span.  Produces an immutable ``_StepPlan``.
  submit  — dispatch the plan to the device: whole-prompt prefills +
            state scatters, suffix chunks, then ONE fused decode scan
            covering all ``decode_steps`` for every decodable slot
            (``lax.scan`` — one dispatch per cycle instead of one per
            token).  Host positions advance immediately (deterministic
            once planned), so the *next* plan can run while this step is
            still executing.
  retire  — materialise the *previous* cycle's results (the only host <-
            device sync on the untraced path) and emit its tokens in the
            exact order the synchronous engine would have: admission
            first-tokens, chunk-completion first-tokens, then decode rows
            step-major / slot-minor.  Completion, EOS cuts, and stream
            callbacks all happen here.

With ``pipeline_depth=2`` (default) step N+1 plans and submits while step
N's device work is in flight and retires N afterwards — the device never
waits for host planning, and the host never blocks mid-cycle.
``pipeline_depth=1`` retires each cycle immediately after submitting it
(the synchronous escape hatch, ``--sync`` on the launchers).  Token
identity is the non-negotiable gate: per-slot decode budgets are computed
exactly (pending in-flight emissions are subtracted from the remaining
token budget), speculative rows past an EOS cut are dropped at retire,
and a preempted victim's in-flight tokens still emit before it can be
re-admitted — async output matches the depth-1 engine token for token.

Prefill compiles are bounded: prompt/chunk lengths are padded to power-of-
two buckets with masked tails (``ServeConfig.prefill_bucket``), so the jit
cache holds O(log max_seq_len) entries instead of one per distinct prompt
length (``metrics.compile_count`` tracks traces).  Recurrent families
(whose state a masked tail would corrupt) keep exact-length prefills.

KV memory is page-granular for every family with a ``KVLayout``
(``repro.serving.layouts``); pages are allocated lazily as positions cross
page boundaries — for the fused scan the whole ``limits[slot]``-long span
is prepared up front (``ensure_decode_capacity(steps=...)``), ring cells
rotating / copy-on-writing before the dispatch so the scan writes only
into private prepared pages.  On page pressure the engine preempts the
youngest lowest-priority request (resume re-prefills; emitted tokens are
kept, so greedy output is unchanged).  Recurrent families (RG-LRU / RWKV:
O(1) state per slot — nothing to page) fall back to the slotted pool;
``ServeConfig.kv_layout`` forces either layout.

Decoding is greedy (argmax) by default and per-request sampled on demand
(``submit(..., sampling=SamplingParams(...))``): the sampler keys a
counter-based PRNG by (request seed, absolute token index) — see
``repro.serving.sampling`` — so batched serving stays *token-identical*
to an unbatched sequential decode of each request whatever the batch
composition, slot assignment, KV layout, mesh or pipeline depth: the
serving analogue of the paper's Fig. 7 equivalence claim (tested in
tests/test_serving.py, tests/test_prefix_cache.py, tests/test_sampling.py
and, for the pipeline itself, tests/test_pipeline.py).  Temperature 0 is
lowered to argmax, so all-greedy traffic dispatches the exact greedy scan
(byte-identical tokens, no extra compiles).  The constructor's ``seed``
initialises *parameters* only (when ``params`` is None) — sampling seeds
are strictly per-request, never global engine state.

Speculative decoding (``ServeConfig.enable_spec``, paged layouts with a
``PagedVerifyContract``): a host-side n-gram drafter proposes up to
``spec_tokens`` continuations per eligible slot; submit runs ONE verify
forward over [last token, drafts] (prefill-style scatter, so accepted KV
lands directly in the slot's pages); retire accepts the longest prefix of
drafts that deterministically replays what the non-speculative engine
would have emitted, rewinds the slot past the first mismatch and emits
accepted tokens + the correction token.  Because verification replays the
exact sampler (argmax when greedy), spec-on output is token-identical to
spec-off — speculation only changes *when* tokens are computed, never
*which*.

Mesh transparency: pass a ``MeshConfig`` and the engine places parameters
via the same logical-axis rules as ``TransparentTrainer`` (tensor-parallel
decode over "model") and shards the slot pool over the data axes
(data-parallel replica serving).  No user code changes — the config *is*
the deployment.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MeshConfig, ModelConfig, ServeConfig
from repro.models import common, registry
from repro.obs import (INFLIGHT_COUNTER, NULL_TRACER, Tracer, request_track,
                       write_chrome_trace)
from repro.serving.kvcache import SlotKVCachePool
from repro.serving.layouts import quantized_layout
from repro.serving.metrics import ServingMetrics
from repro.serving.paged import PagedKVCachePool
from repro.serving.sampling import (GREEDY, PACKED_WIDTH, SamplingParams,
                                    pack_params, sample_tokens)
from repro.serving.scheduler import Request, Scheduler
from repro.serving.spec import DrafterPool

P = jax.sharding.PartitionSpec

# stream callback: (request_id, token, done) -> None
StreamFn = Callable[[int, int, bool], None]

#: smallest prefill bucket — below this the pad overhead beats the compile
_MIN_BUCKET = 16


def bucket_len(n: int, cap: int, *, floor: int = _MIN_BUCKET) -> int:
    """Smallest power-of-two >= n (floored at ``floor``), clamped to the
    cache capacity ``cap`` — the final bucket is the capacity itself, so
    every admissible length lands in O(log cap) distinct shapes."""
    assert 1 <= n <= cap, (n, cap)
    return min(max(floor, 1 << (n - 1).bit_length()), cap)


class _PrefillJob:
    """Host-side progress of one request's chunked suffix prefill."""

    __slots__ = ("req", "prompt", "done")

    def __init__(self, req: Request, prompt: Tuple[int, ...], done: int):
        self.req = req
        self.prompt = prompt
        self.done = done                  # tokens already cached


class _AdmitPlan:
    """A planned whole-prompt admission (slot already allocated).

    ``cached_tok`` set means the full-hit fast path: every prompt block is
    mapped from the prefix cache AND the pool remembered the greedy next
    token, so submit dispatches nothing for this admission — it just seeds
    the device token chain and the slot decodes this same cycle."""

    __slots__ = ("req", "slot", "prompt", "cached_tok")

    def __init__(self, req: Request, slot: int, prompt: Tuple[int, ...],
                 cached_tok=None):
        self.req = req
        self.slot = slot
        self.prompt = prompt
        self.cached_tok = cached_tok


class _ChunkPlan:
    """One planned suffix chunk (pages already prepared)."""

    __slots__ = ("job", "slot", "start", "chunk", "completes")

    def __init__(self, job: _PrefillJob, slot: int, start: int, chunk: int,
                 completes: bool):
        self.job = job
        self.slot = slot
        self.start = start
        self.chunk = chunk
        self.completes = completes


class _SpecPlan:
    """One planned speculative verify: a slot whose decode row is swapped
    for a single drafted-token verification forward this cycle."""

    __slots__ = ("req", "slot", "drafts", "start", "m")

    def __init__(self, req: Request, slot: int, drafts: Tuple[int, ...],
                 start: int, m: int):
        self.req = req
        self.slot = slot
        self.drafts = drafts              # m drafted token ids
        self.start = start                # pool pos = index of last token
        self.m = m


class _StepPlan:
    """Output of the plan phase: everything submit dispatches.  The draft
    phase (``_plan_spec``) may swap decode rows for ``specs`` entries
    before submit; after that the plan is frozen."""

    __slots__ = ("admits", "chunks", "rows", "limits", "mask", "specs")

    def __init__(self, admits, chunks, rows, limits, mask):
        self.admits: List[_AdmitPlan] = admits
        self.chunks: List[_ChunkPlan] = chunks
        self.rows: List[Tuple[int, int]] = rows      # (slot, rid), decodable
        self.limits: Dict[int, int] = limits         # slot -> decode budget
        self.mask: Tuple[int, ...] = mask            # slots masked to trash
        self.specs: List[_SpecPlan] = []             # draft-phase verify jobs


class _InFlight:
    """One submitted-but-not-retired cycle: device handles + emission order.

    ``overrides`` are the prefill-origin first tokens (device scalars —
    forcing them keeps the host out of the token chain), in the exact order
    the synchronous engine would have emitted them; ``stack`` is the decode
    scan's [decode_steps, slots] token matrix, read row-by-row at retire;
    ``specs`` holds the cycle's speculative verifies as one batched
    triple (plans, emit [N, width], nacc [N]) — all slots share a single
    device dispatch and a single host sync at retire.
    """

    __slots__ = ("overrides", "rows", "limits", "stack", "n_steps", "specs")

    def __init__(self, overrides, rows, limits, stack, n_steps, specs=None):
        self.overrides: List[Tuple[int, int, jax.Array]] = overrides
        self.rows: List[Tuple[int, int]] = rows
        self.limits: Dict[int, int] = limits
        self.stack = stack                           # device [n_steps, slots]
        self.n_steps = n_steps
        self.specs: Optional[Tuple[List[_SpecPlan], jax.Array, jax.Array]] = \
            specs


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig,
                 serve_cfg: Optional[ServeConfig] = None, *,
                 params=None, mesh_cfg: Optional[MeshConfig] = None,
                 seed: int = 0, clock=None):
        self.model_cfg = model_cfg
        # (ServeConfig self-validates at construction — no re-check here)
        self.cfg = serve_cfg or ServeConfig()
        # observability: one engine-owned Tracer (ServeConfig(trace=True))
        # threaded through scheduler, pool and metrics; NULL_TRACER keeps
        # every emit a no-op attribute call when tracing is off.  The
        # injectable clock is shared with metrics so deterministic tests
        # see one consistent timeline across both.
        self.tracer = (Tracer(clock=clock, capacity=self.cfg.trace_capacity,
                              meta={"model": model_cfg.name,
                                    "family": model_cfg.family,
                                    "backend": jax.default_backend()})
                       if self.cfg.trace else NULL_TRACER)
        # traced mode fences device calls (block_until_ready) so host vs
        # device time separates; untraced, dispatch stays fully async
        self._fence = (jax.block_until_ready if self.tracer.enabled
                       else (lambda x: x))
        self.bundle = registry.build(model_cfg)
        caps = self.bundle.capabilities()
        if "serve" not in caps:
            raise ValueError(
                f"{model_cfg.name} ({model_cfg.family}) has no serving "
                "decode-path contract (ServeContract); encdec/vlm "
                "frontends need per-request modality inputs — see ROADMAP")

        # -- mesh placement (config-selected, transparent to callers) -----
        self.mesh = None
        dp_axes, dp_total, model_size = (), 1, 1
        if mesh_cfg is not None:
            from repro.launch import mesh as mesh_mod
            mesh_cfg.validate()
            self.mesh = mesh_mod.build_mesh(mesh_cfg)
            dp_axes = mesh_cfg.dp_axes
            dp_total = mesh_mod.dp_size(mesh_cfg)
            model_size = mesh_mod.model_size(mesh_cfg)
            rules = common.rules_for(mesh_cfg, model_cfg)
            param_sh = common.logical_to_mesh(self.bundle.specs, self.mesh,
                                              rules)
        if params is None:
            # ``seed`` initialises parameters ONLY.  Sampling randomness is
            # strictly per-request (SamplingParams.seed + absolute token
            # index) — engine-level state never leaks into token draws.
            params = self.bundle.init_params(jax.random.PRNGKey(seed))
        if self.mesh is not None:
            params = jax.device_put(params, param_sh)
        self.params = params

        # -- KV pool: page-granular when the family has a KVLayout (the
        # layout seam is the capability authority: per-head k/v, latent, or
        # ring-wrapped window pages; recurrent families' O(1) state has no
        # layout and stays slot-granular)
        self.layout = self.bundle.kv_layout
        self.paged = ("paged_serve" in caps
                      and self.cfg.kv_layout != "slotted")
        if self.cfg.kv_layout == "paged" and not self.paged:
            raise ValueError(
                f"{model_cfg.name} ({model_cfg.family}/{model_cfg.attn_kind})"
                " has no paged decode path (PagedServeContract / KVLayout); "
                "recurrent families' O(1) state uses the slotted pool "
                "(kv_layout='auto')")
        # prefix-cache page sharing + chunked prefill need the paged
        # prefill contract (engine writes pages in place, no state scatter)
        self._prefix_path = self.paged and "prefix_serve" in caps
        # speculative decoding needs the all-position verify head
        # (PagedVerifyContract -> "spec_serve"); ServeConfig.enable_spec
        # gates it per deployment, slotted layouts have no page rewind
        self._spec_path = (self.paged and "spec_serve" in caps
                           and self.cfg.enable_spec)
        # masked-tail power-of-two bucketing of whole-prompt prefills
        self._bucket_slotted = (self.cfg.prefill_bucket
                                and "bucketed_prefill" in caps)
        if self.paged:
            # windowed families: a page must fit (and tile) the window —
            # fail here with one ServeConfig-level error, not deep in the
            # pool or a kernel
            self.cfg.check_window(self.layout.window)
            # quantized pools: rewrite the layout's page leaves to int8 +
            # per-(page, offset, kv-head) fp32 scales.  Same single-error
            # discipline as check_window — int8 + MLA latents (or a family
            # that resolved slotted) fails here naming both knobs, not deep
            # in a kernel
            if self.cfg.kv_dtype != "fp32":
                self.cfg.check_kv_dtype(self.layout)
                self.layout = quantized_layout(self.layout,
                                               self.cfg.kv_dtype)
            self.pool = PagedKVCachePool(
                self.cfg.max_batch, self.cfg.page_size, self.cfg.max_seq_len,
                lambda: self.bundle.init_decode_state(1, self.cfg.page_size),
                num_pages=self.cfg.num_pages, mesh=self.mesh,
                model_size=model_size, layout=self.layout,
                enable_prefix_cache=(self.cfg.enable_prefix_cache
                                     and self._prefix_path),
                tracer=self.tracer)
            self._cache_len = self.pool.padded_len   # page-multiple prefill
            # ring chunks are capped at the window: a longer write-then-
            # attend chunk would wrap onto cells its own queries still need
            self._chunk_cap = self.layout.max_chunk_tokens(
                self.pool.padded_len)
            # the fused scan has the same wrap hazard: its span may not
            # exceed the window (contiguous layouts are unconstrained)
            self._span_cap = self.layout.max_decode_span(self.cfg.decode_steps)
        else:
            if self.cfg.kv_dtype != "fp32":
                # auto-resolved slotted (no KVLayout): same error the
                # explicit kv_layout='slotted' combination gets in validate()
                self.cfg.check_kv_dtype(None)
            self.pool = SlotKVCachePool(
                self.cfg.max_batch,
                lambda: self.bundle.init_decode_state(1, self.cfg.max_seq_len),
                mesh=self.mesh, dp_axes=dp_axes, dp_total=dp_total,
                model_size=model_size)
            self._cache_len = self.cfg.max_seq_len
            self._span_cap = self.cfg.decode_steps

        self.scheduler = Scheduler(self.cfg, tracer=self.tracer)
        self.metrics = ServingMetrics(clock, tracer=self.tracer)
        self.requests: Dict[int, Request] = {}
        self.results: Dict[int, List[int]] = {}
        self._rid = itertools.count()
        self._prefilling: Dict[int, _PrefillJob] = {}   # slot -> job
        # pipeline state: the one submitted-but-not-retired cycle, the
        # per-request count of its not-yet-emitted tokens (subtracted from
        # decode budgets so the pipeline never over-generates), and the
        # device-resident last token per slot (decode feeds decode without
        # a host round-trip; prefill logits override via one jitted setter)
        self._inflight: Optional[_InFlight] = None
        self._pending: Dict[int, int] = {}              # rid -> tokens in flight
        self._last_toks_dev = jnp.zeros((self.cfg.max_batch,), jnp.int32)
        self.prefill_compiles = 0         # lifetime (metrics.reset survives)
        # speculative decoding: per-request n-gram drafters plus the slots
        # whose verify is in flight (those slots must not decode, draft
        # again, or be preempted until the verify retires)
        self._drafters = DrafterPool()
        self._spec_wait: set = set()
        # host mirror of each slot's next sampling index (= prompt+tokens
        # length); the slotted sampled scan needs it as an operand (the
        # paged pool carries pos on-device already)
        self._slot_pos = np.zeros((self.cfg.max_batch,), np.int64)

        # -- compiled entry points -----------------------------------------
        # prefill compiles are counted at trace time: a wrapper bump runs
        # once per new jit cache entry, which is exactly the XLA compile
        # count the bucketing is there to bound
        def _counted(fn):
            def wrapped(*a, **k):
                self.prefill_compiles += 1
                self.metrics.record_prefill_compile()
                # a[1] is the token operand: its (traced) shape is the
                # bucket this compile covers
                self.tracer.instant("prefill.compile",
                                    shape=list(a[1].shape))
                return fn(*a, **k)
            return wrapped

        # whole-prompt prefill: one jit object; XLA caches per
        # (bucket_len | prompt_len, cache_len) pair
        self._prefill = jax.jit(_counted(self.bundle.serve_prefill_fn),
                                static_argnames=("cache_len",))
        # tiny helpers keeping the token chain on-device: force a slot's
        # next token from prefill logits / read the greedy argmax — each
        # compiles once
        self._argmax1 = jax.jit(
            lambda logits: jnp.argmax(logits[0]).astype(jnp.int32))
        # sampled sibling of _argmax1: draw the prefill-origin first token
        # with the request's packed params at its absolute index
        self._sample1 = jax.jit(
            lambda logits, packed, idx: sample_tokens(
                logits, packed[None, :], idx[None])[0])
        self._set_tok = jax.jit(
            lambda toks, slot, tok: toks.at[slot].set(tok))

        decode_fn = self.bundle.decode_fn
        paged_decode_fn = self.bundle.paged_decode_fn
        paged_prefill_fn = self.bundle.paged_prefill_fn
        paged_verify_fn = self.bundle.paged_verify_fn
        n_steps = self.cfg.decode_steps
        spec_width = self.cfg.spec_tokens + 1   # last token + drafts

        # backend-selected like core/allreduce: the Pallas paged-attention
        # kernels on TPU (HBM traffic ~ pages held), traced ref gather on
        # CPU.  ServeConfig.use_pallas overrides (off-TPU the kernels run
        # in interpret mode — the ops wrappers select it automatically), so
        # tests/CI exercise the kernel paths everywhere.  Applies to every
        # paged dispatch: decode scans, prefill chunks, and spec-verify.
        if self.cfg.use_pallas is None:
            paged_kernel = jax.default_backend() == "tpu"
        else:
            paged_kernel = self.cfg.use_pallas
        self.paged_kernel = paged_kernel and self.paged

        # One fused dispatch per cycle: lax.scan over decode_steps.  Each
        # slot decodes exactly ``limits[slot]`` tokens; past its budget the
        # carry freezes — the frozen iterations idempotently replay the
        # last in-budget step (same token, same prepared position, same
        # deterministic K/V write), so no slot writes past its span and
        # the stacked output rows past the budget are ignored at retire.
        # ``last`` (the next cycle's token chain) is ``stack[-1]`` for any
        # slot that decoded at all: the frozen replays re-emit the last
        # in-budget token, so the final stack row IS ``stack[limit-1]``.
        # A budget below n_steps does NOT mean the request completes —
        # ``safe_decode_span`` caps continuing ring slots too — so the
        # chain must stay live; only limit-0 slots keep their input token.
        # ``packed`` is the pool's fused [slots, width+2] operand — page
        # table | pos | limits in one upload (see decode_operands); the
        # slices below are free under jit
        def _decode_scan_paged(params, toks0, pages, packed):
            table = packed[:, :-2]
            pos0 = packed[:, -2]
            limits = packed[:, -1]

            def body(carry, k):
                toks, pos, pages = carry
                logits, pages = paged_decode_fn(
                    params, toks[:, None],
                    {"pages": pages, "page_table": table, "pos": pos},
                    use_pallas=paged_kernel)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                adv = (k + 1) < limits
                return ((jnp.where(adv, nxt, toks),
                         jnp.where(adv, pos + 1, pos), pages), nxt)
            (_, _, pages), stack = jax.lax.scan(
                body, (toks0, pos0, pages), jnp.arange(n_steps))
            last = jnp.where(limits >= 1, stack[-1], toks0)
            return stack, last, pages

        # slotted scan: no freeze needed for state — slots past their
        # budget only ever complete (and evict/blank) or are free (and are
        # overwritten by the next insert), exactly the slots the
        # synchronous engine also decoded junk into
        def _decode_scan(params, toks0, pool_state, limits):
            def body(carry, k):
                toks, state = carry
                logits, state = jax.vmap(decode_fn, in_axes=(None, 0, 0))(
                    params, toks[:, None, None], state)
                nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
                adv = (k + 1) < limits
                return (jnp.where(adv, nxt, toks), state), nxt
            (_, state), stack = jax.lax.scan(
                body, (toks0, pool_state), jnp.arange(n_steps))
            last = jnp.where(limits >= 1, stack[-1], toks0)
            return stack, last, state

        # sampled twins of the two scans: one extra packed [slots, 4]
        # operand (bitcast temperature/top_p | top_k | seed, see
        # sampling.pack_params) and the absolute token index threaded into
        # the counter-based PRNG.  Rows whose request is greedy lower to
        # argmax inside sample_tokens, so mixed batches stay exact; all-
        # greedy cycles dispatch the plain scans above (byte identity, no
        # sampling operand, no recompile of the greedy path).
        def _decode_scan_paged_sampled(params, toks0, pages, packed, samp):
            table = packed[:, :-2]
            pos0 = packed[:, -2]
            limits = packed[:, -1]

            def body(carry, k):
                toks, pos, pages = carry
                logits, pages = paged_decode_fn(
                    params, toks[:, None],
                    {"pages": pages, "page_table": table, "pos": pos},
                    use_pallas=paged_kernel)
                # the input token sits at pos -> its successor's absolute
                # index is pos + 1; frozen rows idempotently replay the
                # same index, same draw
                nxt = sample_tokens(logits, samp, pos + 1)
                adv = (k + 1) < limits
                return ((jnp.where(adv, nxt, toks),
                         jnp.where(adv, pos + 1, pos), pages), nxt)
            (_, _, pages), stack = jax.lax.scan(
                body, (toks0, pos0, pages), jnp.arange(n_steps))
            last = jnp.where(limits >= 1, stack[-1], toks0)
            return stack, last, pages

        def _decode_scan_sampled(params, toks0, pool_state, limits, samp,
                                 pos0):
            def body(carry, k):
                toks, state, pos = carry
                logits, state = jax.vmap(decode_fn, in_axes=(None, 0, 0))(
                    params, toks[:, None, None], state)
                nxt = sample_tokens(logits[:, 0, :], samp, pos)
                adv = (k + 1) < limits
                return (jnp.where(adv, nxt, toks), state,
                        jnp.where(adv, pos + 1, pos)), nxt
            (_, state, _), stack = jax.lax.scan(
                body, (toks0, pool_state, pos0), jnp.arange(n_steps))
            last = jnp.where(limits >= 1, stack[-1], toks0)
            return stack, last, state

        def _prefill_chunk(params, toks, pages, table, start, n_valid):
            """One request's suffix chunk straight into the page pool
            (pages donated; the scalar/table operands are tiny uploads)."""
            return paged_prefill_fn(params, toks,
                                    {"pages": pages, "page_table": table,
                                     "start": start, "n_valid": n_valid},
                                    use_pallas=paged_kernel)

        verify_tw = self.pool.table_width if self.paged else 0

        def _verify_step(params, toks, pages, packed):
            """One batched speculative verify: every speculating slot's
            [last token, drafts] row forwards through the all-position
            head in a single dispatch (a scan threads the shared pool
            through the rows), then the sampler deterministically replays
            at every drafted index and the accepted prefix is counted
            on-device.

            ``toks`` is [N, spec_width]; ``packed`` is one int32 matrix —
            ``[page table | start | n_valid | sampling params | drafts]``
            per row — so a cycle's whole verify work ships as two host
            uploads however many slots speculate.  Logits row j predicts
            absolute index ``start + 1 + j``; draft j is accepted iff it
            equals exactly the token the non-speculative engine would
            emit there (argmax when greedy, the counter-keyed draw
            otherwise), so acceptance never changes the output stream.
            Padding drafts are -1 and auto-reject, clamping ``nacc`` to
            the real draft count; all-padding rows (``n_valid`` 0) mask
            every position into the trash page."""
            table = packed[:, :verify_tw]
            start = packed[:, verify_tw]
            n_valid = packed[:, verify_tw + 1]
            samp = packed[:, verify_tw + 2:verify_tw + 2 + PACKED_WIDTH]
            drafts = packed[:, verify_tw + 2 + PACKED_WIDTH:]

            def body(pages, row):
                t, tab, st, nv = row
                logits, pages = paged_verify_fn(
                    params, t[None], {"pages": pages, "page_table": tab,
                                      "start": st, "n_valid": nv},
                    use_pallas=paged_kernel)
                return pages, logits

            pages, stack = jax.lax.scan(body, pages,
                                        (toks, table, start, n_valid))
            n = stack.shape[0]
            idx = start[:, None] + 1 + jnp.arange(spec_width,
                                                  dtype=jnp.int32)[None, :]
            rows = jnp.broadcast_to(samp[:, None, :],
                                    (n, spec_width, PACKED_WIDTH))
            emit = sample_tokens(
                stack.reshape(n * spec_width, -1),
                rows.reshape(n * spec_width, PACKED_WIDTH),
                idx.reshape(n * spec_width)).reshape(n, spec_width)
            match = (emit[:, :-1] == drafts) & (drafts >= 0)
            nacc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                           axis=1)
            return emit, nacc, pages

        if self.mesh is not None:
            def ns(spec):
                return jax.sharding.NamedSharding(self.mesh, spec)

            # token / limit vectors are tiny [slots] operands — replicated
            # (the old per-step path dp-sharded toks; at scan granularity
            # the transfer is once per cycle and replication is simpler)
            if self.paged:
                self._decode = jax.jit(
                    _decode_scan_paged,
                    in_shardings=(param_sh, ns(P(None)),
                                  self.pool.shardings,
                                  ns(P(None, None))),
                    out_shardings=(ns(P(None, None)), ns(P(None)),
                                   self.pool.shardings),
                    donate_argnums=(2,))
                self._decode_sampled = jax.jit(
                    _decode_scan_paged_sampled,
                    in_shardings=(param_sh, ns(P(None)),
                                  self.pool.shardings,
                                  ns(P(None, None)), ns(P(None, None))),
                    out_shardings=(ns(P(None, None)), ns(P(None)),
                                   self.pool.shardings),
                    donate_argnums=(2,))
                if self._prefix_path:
                    self._paged_prefill = jax.jit(
                        _counted(_prefill_chunk),
                        in_shardings=(param_sh, ns(P(None, None)),
                                      self.pool.shardings, ns(P(None)),
                                      ns(P()), ns(P())),
                        out_shardings=(ns(P(None, None)),
                                       self.pool.shardings),
                        donate_argnums=(2,))
                if self._spec_path:
                    self._verify = jax.jit(
                        _verify_step,
                        in_shardings=(param_sh, ns(P(None, None)),
                                      self.pool.shardings,
                                      ns(P(None, None))),
                        out_shardings=(ns(P(None, None)), ns(P(None)),
                                       self.pool.shardings),
                        donate_argnums=(2,))
            else:
                self._decode = jax.jit(
                    _decode_scan,
                    in_shardings=(param_sh, ns(P(None)),
                                  self.pool.shardings, ns(P(None))),
                    out_shardings=(ns(P(None, None)), ns(P(None)),
                                   self.pool.shardings),
                    donate_argnums=(2,))
                self._decode_sampled = jax.jit(
                    _decode_scan_sampled,
                    in_shardings=(param_sh, ns(P(None)),
                                  self.pool.shardings, ns(P(None)),
                                  ns(P(None, None)), ns(P(None))),
                    out_shardings=(ns(P(None, None)), ns(P(None)),
                                   self.pool.shardings),
                    donate_argnums=(2,))
        elif self.paged:
            self._decode = jax.jit(_decode_scan_paged, donate_argnums=(2,))
            self._decode_sampled = jax.jit(_decode_scan_paged_sampled,
                                           donate_argnums=(2,))
            if self._prefix_path:
                self._paged_prefill = jax.jit(_counted(_prefill_chunk),
                                              donate_argnums=(2,))
            if self._spec_path:
                self._verify = jax.jit(_verify_step, donate_argnums=(2,))
        else:
            self._decode = jax.jit(_decode_scan, donate_argnums=(2,))
            self._decode_sampled = jax.jit(_decode_scan_sampled,
                                           donate_argnums=(2,))

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               priority: int = 0, deadline: Optional[float] = None,
               sampling: Optional[SamplingParams] = None
               ) -> Optional[int]:
        """Queue one request.  Returns its id, or None when the admission
        queue is full (caller sheds load / retries).

        ``sampling`` (default greedy) travels with the request: its seed
        plus the token's absolute index fully determine every draw, so the
        output is a pure function of (prompt, params) — independent of
        batch composition, slot assignment or engine configuration."""
        prompt = tuple(int(t) for t in prompt)
        max_new = (self.cfg.max_new_tokens if max_new_tokens is None
                   else max_new_tokens)
        if max_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new}")
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.cfg.max_seq_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"slot capacity max_seq_len={self.cfg.max_seq_len}")
        if sampling is None:
            sampling = GREEDY
        elif not isinstance(sampling, SamplingParams):
            raise TypeError(
                f"sampling must be a SamplingParams, got {type(sampling)}")
        rid = next(self._rid)
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                      priority=priority, deadline=deadline,
                      sampling=sampling)
        if not self.scheduler.submit(req):
            self.metrics.record_reject()
            return None
        self.requests[rid] = req
        self.metrics.record_submit(rid)
        self.tracer.begin("queued", track=request_track(rid),
                          prompt_tokens=len(prompt))
        return rid

    # ------------------------------------------------------------------
    # Event loop
    # ------------------------------------------------------------------

    @property
    def busy(self) -> bool:
        return bool(self.scheduler.depth() or self.pool.owner
                    or self._inflight is not None)

    def _emit(self, req: Request, token: int, stream: Optional[StreamFn]):
        first = not req.tokens
        req.tokens.append(token)
        if first:
            # a resumed preemptee keeps its tokens, so ``first`` is only
            # true on the genuine first emission (even if the request was
            # bounced at admission before ever running)
            self.metrics.record_first_token(req.rid)
        else:
            self.metrics.record_token(req.rid)
        done = self._finished(req, token)
        if stream is not None:
            stream(req.rid, token, done)
        return done

    def _finished(self, req: Request, token: int) -> bool:
        if self.cfg.eos_token >= 0 and token == self.cfg.eos_token:
            return True
        return len(req.tokens) >= req.max_new_tokens

    def _complete(self, slot: int, req: Request):
        self.pool.evict(slot)
        self.results[req.rid] = req.tokens
        self.metrics.record_completion(req.rid)
        rt = request_track(req.rid)
        self.tracer.end("decode", track=rt, tokens=len(req.tokens))
        self.tracer.instant("request.complete", track=rt, rid=req.rid,
                            tokens=len(req.tokens),
                            preempted=req.preempted)

    def _finalize(self, slot: int, req: Request):
        """A retired token finished ``req``.  Normally its slot is evicted;
        if it was preempted *after* this cycle was submitted (the slot now
        belongs to someone else or is free), the request is a ghost — its
        in-flight tokens completed it, so it leaves the waiting queue
        without ever being re-admitted."""
        self._drafters.drop(req.rid)
        if self.pool.owner.get(slot) == req.rid:
            self._complete(slot, req)
            return
        self.scheduler.drop(req)
        self.results[req.rid] = req.tokens
        self.metrics.record_completion(req.rid)
        rt = request_track(req.rid)
        self.tracer.end("queued", track=rt)    # re-queued by the preemption
        self.tracer.instant("request.complete", track=rt, rid=req.rid,
                            tokens=len(req.tokens),
                            preempted=req.preempted)

    def _can_admit(self, prompt) -> bool:
        """Would the paged pool take this prompt right now (slot + pages,
        net of prefix-cache hits)?  Used by the priority policy's
        blocked-admission check only — actual admission goes straight
        through the plan phase's ``alloc_*`` (no double planning: the
        pool memoizes the prompt plan by chain hash)."""
        return self.pool.can_admit_prompt(prompt) if self._prefix_path \
            else self.pool.can_admit(len(prompt))

    def _bucketed_prompt(self, prompt, cap: int):
        """(tokens [1, S], n_valid_or_None): pad to a power-of-two bucket
        when the family supports masked tails, else the exact length."""
        n = len(prompt)
        if not self._bucket_slotted:
            return jnp.asarray(np.asarray(prompt, np.int32)[None, :]), None
        toks = np.zeros((1, bucket_len(n, cap)), np.int32)
        toks[0, :n] = prompt
        return jnp.asarray(toks), n

    # ------------------------------------------------------------------
    # Phase 1: plan (host only — decides, allocates, prepares; no blocking)
    # ------------------------------------------------------------------

    def _plan_admit(self, req: Request, admits: List[_AdmitPlan]) -> bool:
        """Reserve a slot (and pages) for one request; False when the pool
        cannot take it right now (the caller re-queues it, never drops
        it).  The pool is the single admission authority: no pre-check
        re-plans the prompt."""
        prompt = req.resume_prompt()
        rt = request_track(req.rid)
        if self._prefix_path:
            # map cached prefix pages read-only; suffix prefills in chunks
            # (the first chunk is planned this same cycle).  The greedy
            # next-token memo only holds for greedy requests — a sampled
            # request re-prefills its final position and draws its own
            # first token (use_memo=False caps the hit at plen - 1)
            out = self.pool.alloc_prefix(req.rid, prompt,
                                         use_memo=req.sampling.greedy)
            if out is None:
                return False
            slot, cached = out
            if cached:
                self.metrics.record_prefix_hit(cached)
            self.tracer.end("queued", track=rt)
            self.tracer.begin("prefill", track=rt,
                              prompt_tokens=len(prompt),
                              prefix_hit_tokens=cached)
            if cached >= len(prompt):
                # full hit + memoized next token (cache_next_token): no
                # prefill at all — the admission joins this cycle's decode
                # rows like a completed prefill would
                admits.append(_AdmitPlan(
                    req, slot, prompt,
                    cached_tok=self.pool.cached_next_token(prompt)))
            else:
                self._prefilling[slot] = _PrefillJob(req, prompt, cached)
            return True
        if self.paged:
            slot = self.pool.alloc_for_insert(req.rid, len(prompt))
        else:
            slot = self.pool.alloc(req.rid)
        if slot is None:
            return False
        self.tracer.end("queued", track=rt)
        self.tracer.begin("prefill", track=rt, prompt_tokens=len(prompt))
        admits.append(_AdmitPlan(req, slot, prompt))
        return True

    def _plan_chunks(self, chunks: List[_ChunkPlan]) -> None:
        """Size one suffix chunk per prefilling slot and prepare its pages
        (ring rotation / COW).  A slot whose chunk finishes the prompt
        leaves ``_prefilling`` now — it joins this same cycle's decode
        rows, exactly when the synchronous engine would have started
        decoding it."""
        for slot in sorted(self._prefilling):
            job = self._prefilling.get(slot)
            if job is None:                 # preempted by an earlier slot's
                continue                    # pressure relief this cycle
            remaining = len(job.prompt) - job.done
            chunk = min(remaining, self.cfg.prefill_chunk_tokens
                        or self._chunk_cap, self._chunk_cap)
            if not self.pool.prepare_chunk(slot, job.done,
                                           job.done + chunk - 1):
                # page pressure mid-prefill (ring rotation needed a COW or
                # fresh page): relieve it like decode growth does — preempt
                # the lowest-priority youngest other request, else bounce
                # this one back to the queue and retry next cycle
                self._relieve_pressure(prefer_not=slot)
                if slot not in self._prefilling or \
                        not self.pool.prepare_chunk(slot, job.done,
                                                    job.done + chunk - 1):
                    continue
            completes = job.done + chunk >= len(job.prompt)
            chunks.append(_ChunkPlan(job, slot, job.done, chunk, completes))
            if completes:
                del self._prefilling[slot]

    def _plan_cycle(self) -> _StepPlan:
        cfg, tr = self.cfg, self.tracer
        # requests with un-retired tokens in flight must not be re-admitted
        # (their resume prompt would miss those tokens); the guard clears
        # at the next retire
        skip_rids = frozenset(self._pending)
        # 1. preemption (priority policy only): fires when admission is
        # blocked — no free slot, or (paged) too few free pages for the
        # most urgent waiter's prompt (prefix-cache hits shrink that need)
        with tr.span("preempt"):
            if cfg.policy == "priority" and self.scheduler.depth():
                head = self.scheduler.peek()
                blocked = (head.rid not in skip_rids
                           and (self.pool.free_slots == 0
                                or (self.paged
                                    and not self._can_admit(
                                        head.resume_prompt()))))
                if blocked:
                    # slots with a speculative verify in flight cannot be
                    # evicted (their retire rewinds pool state in place)
                    running = {s: self.requests[r]
                               for s, r in self.pool.owner.items()
                               if s not in self._spec_wait}
                    for slot, _ in self.scheduler.preemption(running):
                        self._preempt(slot)
        # 2. admission: reserve prefix pages / slots.  When the pool
        # declines (slot free but pages aren't), wait for running work to
        # finish: EVERY not-yet-admitted popped request goes back
        # (reversed, so the head of the line ends up most negative =
        # first) — head-of-line blocking, never a silent drop.
        admits: List[_AdmitPlan] = []
        with tr.span("admit"):
            pending = self.scheduler.next_prefills(self.pool.free_slots,
                                                   skip_rids)
            for i, req in enumerate(pending):
                if not self._plan_admit(req, admits):
                    for r in reversed(pending[i:]):
                        self.scheduler.push_front(r)
                    break
        # 2b. chunked prefill: one chunk per mid-prefill slot per cycle
        chunks: List[_ChunkPlan] = []
        if self._prefilling:
            self._plan_chunks(chunks)
        self.metrics.sample_queue_depth(self.scheduler.depth())
        # 3. per-slot decode budgets: exactly the tokens the request may
        # still emit, net of everything already in flight and the first
        # token this cycle's own prefill will force — the pipeline never
        # over-generates, so EOS-free runs are token-exact by construction
        chunk_done = {c.slot for c in chunks if c.completes}
        override_slots = {a.slot for a in admits} | chunk_done
        limits: Dict[int, int] = {}
        for slot, rid in self.pool.owner.items():
            if slot in self._prefilling:
                continue
            if slot in self._spec_wait:
                # verify in flight: the slot's pos/token chain is only
                # known after retire — it sits this cycle out (depth 2
                # alternates verify / idle cycles per spec slot)
                limits[slot] = 0
                continue
            req = self.requests[rid]
            budget = (req.max_new_tokens - len(req.tokens)
                      - self._pending.get(rid, 0)
                      - (1 if slot in override_slots else 0))
            lim = max(min(budget, cfg.decode_steps, self._span_cap), 0)
            if lim > 0 and slot in chunk_done and self.paged:
                # the chunk's blocks aren't committed to the prefix index
                # until submit — a ring rotation planned now would strand
                # them (see PagedKVCachePool.safe_decode_span)
                lim = self.pool.safe_decode_span(slot, lim)
            limits[slot] = lim
        # 4. page growth for the whole span (paged): every decodable slot
        # gets positions pos..pos+limit-1 privately writable before the
        # scan is dispatched; on starvation preempt until the rest fit
        if self.paged:
            while True:
                starved = self.pool.ensure_decode_capacity(
                    skip=self._prefilling.keys(), steps=limits)
                if not starved:
                    break
                if not self._relieve_pressure():
                    # every evictable tenant has a verify in flight —
                    # starved slots idle one cycle rather than corrupting
                    # an un-retired speculative state
                    for s in starved:
                        limits[s] = 0
                    break
        # held pages peak right after growth (completion evictions come at
        # retire) — sample here so kv_bytes_peak sees the high-water mark
        self.metrics.sample_kv_bytes(self.pool.kv_bytes_held(),
                                     self.pool.kv_bytes_slotted())
        # 5. growth preemption may have evicted work planned above — keep
        # only what the current ownership map still stands behind (nothing
        # was dispatched yet, so a drop here is clean)
        admits = [a for a in admits
                  if self.pool.owner.get(a.slot) == a.req.rid]
        chunks = [c for c in chunks
                  if self.pool.owner.get(c.slot) == c.job.req.rid]
        rows = [(s, self.pool.owner[s]) for s in sorted(self.pool.owner)
                if s not in self._prefilling and limits.get(s, 0) > 0]
        mask = tuple(sorted(s for s in self.pool.owner
                            if limits.get(s, 0) <= 0))
        return _StepPlan(admits, chunks, rows,
                         {s: limits[s] for s, _ in rows}, mask)

    # ------------------------------------------------------------------
    # Phase 1b: draft (host n-gram proposals; swaps decode rows for
    # verify jobs — runs under the ``step.draft`` trace section)
    # ------------------------------------------------------------------

    def _plan_spec(self, plan: _StepPlan) -> None:
        """Pick decode rows to speculate on and draft their continuations.

        A slot is eligible when the host knows its full token history (no
        un-retired emissions: ``_pending`` is zero and no override was
        planned this cycle) and the drafter proposes at least one token.
        The chosen slot's decode row becomes one verify forward over
        [last token, drafts]; its budget covers at most the draft count +
        the correction token, so acceptance can never over-generate.
        Capacity for the m+1-position write span is ensured here; on
        starvation the slot simply keeps its normal decode row — drafting
        never preempts anyone.

        Pipelined engines (depth > 1) plan while the previous cycle is
        still in flight, so a busy slot never has a complete history at
        plan time and could never bootstrap.  When the drafter already
        holds a continuation for the known prefix, the slot idles for one
        cycle (limit 0) so the in-flight tail retires; the next plan then
        drafts from complete history, and verify/idle alternation sustains
        itself from there."""
        if not self._spec_path or not plan.rows:
            return
        cfg = self.cfg
        override_slots = ({a.slot for a in plan.admits}
                          | {c.slot for c in plan.chunks if c.completes})
        for slot, rid in list(plan.rows):
            if slot in override_slots:
                continue
            req = self.requests[rid]
            pending = self._pending.get(rid, 0)
            if pending:
                if (req.tokens and plan.limits.get(slot, 0) > 0
                        and req.max_new_tokens - len(req.tokens)
                        - pending > 1
                        and self._drafters.propose(
                            rid, req.prompt + tuple(req.tokens), 1)):
                    plan.limits[slot] = 0      # stall: drain, draft next
                continue
            if not req.tokens:
                continue
            budget = req.max_new_tokens - len(req.tokens)
            k = min(cfg.spec_tokens, budget - 1)
            if self.pool.layout.window:
                # ring cells alias position p with p - window: each
                # optimistic verify write clobbers the oldest in-window
                # entry, and a rejection cannot restore it (ring rewind
                # keeps cells).  A single draft only ever clobbers the
                # one position that has already left every window a later
                # token can attend to, so windowed slots draft 1.
                k = 1
            if k < 1:
                continue
            drafts = self._drafters.propose(
                rid, req.prompt + tuple(req.tokens), k)
            if not drafts:
                continue
            m = len(drafts)
            # ring windows: the m+1-token write span must stay
            # rotation-free (verify scatters like a chunk, no rotation)
            span = self.pool.safe_decode_span(slot, m + 1)
            if span < 2:
                continue
            if span < m + 1:
                m = span - 1
                drafts = drafts[:m]
            others = [s for s in self.pool.active_slots if s != slot]
            if self.pool.ensure_decode_capacity(skip=others,
                                                steps={slot: m + 1}):
                continue        # page-starved: fall back to plain decode
            plan.specs.append(_SpecPlan(req, slot, drafts,
                                        int(self.pool.pos[slot]), m))
            plan.rows = [r for r in plan.rows if r[0] != slot]
            plan.limits.pop(slot, None)
            plan.mask = tuple(sorted(set(plan.mask) | {slot}))

    # ------------------------------------------------------------------
    # Phase 2: submit (dispatch the plan; advance host positions; no sync)
    # ------------------------------------------------------------------

    def _submit(self, plan: _StepPlan) -> Optional[_InFlight]:
        cfg, tr = self.cfg, self.tracer
        overrides: List[Tuple[int, int, jax.Array]] = []
        for a in plan.admits:
            rt = request_track(a.req.rid)
            if a.cached_tok is not None:
                # full-hit fast path: pages mapped read-only at plan, next
                # token remembered from an earlier identical prefill — the
                # admission costs zero device dispatches beyond seeding the
                # token chain
                self._last_toks_dev = self._set_tok(self._last_toks_dev,
                                                    a.slot, a.cached_tok)
                self._slot_pos[a.slot] = len(a.prompt) + 1
                overrides.append((a.req.rid, a.slot, a.cached_tok))
                tr.end("prefill", track=rt)
                tr.begin("decode", track=rt)
                continue
            toks, n_valid = self._bucketed_prompt(a.prompt, self._cache_len)
            with tr.span("prefill.device", tokens=len(a.prompt),
                         bucket=int(toks.shape[1])):
                if n_valid is None:
                    logits, state = self._prefill(self.params, toks,
                                                  cache_len=self._cache_len)
                else:
                    logits, state = self._prefill(
                        self.params, toks, cache_len=self._cache_len,
                        n_valid=jnp.asarray(n_valid, jnp.int32))
                self._fence(logits)
            self.metrics.record_prefill(len(a.prompt))
            if self.paged:
                self.pool.insert_state(a.slot, state)
            else:
                self.pool.insert_at(a.slot, state)
            if a.req.sampling.greedy:
                tok = self._argmax1(logits)
            else:
                tok = self._sample1(
                    logits, jnp.asarray(pack_params(a.req.sampling)),
                    jnp.asarray(len(a.prompt), jnp.int32))
            self._last_toks_dev = self._set_tok(self._last_toks_dev,
                                                a.slot, tok)
            self._slot_pos[a.slot] = len(a.prompt) + 1
            overrides.append((a.req.rid, a.slot, tok))
            tr.end("prefill", track=rt)
            tr.begin("decode", track=rt)
        for c in plan.chunks:
            job = c.job
            width = (bucket_len(c.chunk, self.pool.padded_len)
                     if cfg.prefill_bucket else c.chunk)
            ctoks = np.zeros((1, width), np.int32)
            ctoks[0, :c.chunk] = job.prompt[c.start:c.start + c.chunk]
            rt = request_track(job.req.rid)
            with tr.span("prefill.chunk", track=rt, chunk=c.chunk,
                         bucket=width, start=c.start):
                with tr.span("prefill.device", tokens=c.chunk, bucket=width):
                    logits, self.pool.pages = self._paged_prefill(
                        self.params, jnp.asarray(ctoks), self.pool.pages,
                        jnp.asarray(self.pool.tables[c.slot]),
                        jnp.asarray(c.start, jnp.int32),
                        jnp.asarray(c.chunk, jnp.int32))
                    self._fence(logits)
            self.metrics.record_prefill(c.chunk)
            job.done = c.start + c.chunk
            # register fully-written blocks right away: requests admitted
            # next cycle can already share this prefix (device order makes
            # the pages valid before any reader dispatches)
            self.pool.commit_prefix(c.slot, job.prompt[:job.done])
            if c.completes:
                if job.req.sampling.greedy:
                    tok = self._argmax1(logits)
                    # remember (prompt -> next token) so a repeat of this
                    # exact prompt can skip prefill entirely (full-hit
                    # fast path); the memo is greedy-only — a sampled
                    # request's first token depends on its seed
                    self.pool.cache_next_token(job.prompt, tok)
                else:
                    tok = self._sample1(
                        logits, jnp.asarray(pack_params(job.req.sampling)),
                        jnp.asarray(len(job.prompt), jnp.int32))
                self._last_toks_dev = self._set_tok(self._last_toks_dev,
                                                    c.slot, tok)
                self._slot_pos[c.slot] = len(job.prompt) + 1
                overrides.append((job.req.rid, c.slot, tok))
                tr.end("prefill", track=rt)
                tr.begin("decode", track=rt)
        stack = None
        if plan.rows:
            # all-greedy cycles take the plain argmax scan (byte-identical
            # dispatch to the pre-sampling engine); any sampled row routes
            # the whole cycle through the sampled twin, whose greedy rows
            # still lower to argmax inside sample_tokens
            sampled = any(not self.requests[rid].sampling.greedy
                          for _, rid in plan.rows)
            samp_dev = None
            if sampled:
                samp = np.stack(
                    [pack_params(self.requests[self.pool.owner[s]].sampling
                                 if s in self.pool.owner else GREEDY)
                     for s in range(cfg.max_batch)])
                samp_dev = jnp.asarray(samp)
            with tr.span("decode.device", steps=cfg.decode_steps,
                         rows=len(plan.rows), sampled=sampled):
                if self.paged:
                    packed = self.pool.decode_operands(
                        plan.limits, mask_slots=plan.mask)
                    if sampled:
                        stack, self._last_toks_dev, self.pool.pages = \
                            self._decode_sampled(
                                self.params, self._last_toks_dev,
                                self.pool.pages, packed, samp_dev)
                    else:
                        stack, self._last_toks_dev, self.pool.pages = \
                            self._decode(self.params, self._last_toks_dev,
                                         self.pool.pages, packed)
                else:
                    limits_dev = jnp.asarray(np.asarray(
                        [plan.limits.get(s, 0) for s in range(cfg.max_batch)],
                        np.int32))
                    if sampled:
                        pos_dev = jnp.asarray(
                            self._slot_pos.astype(np.int32))
                        stack, self._last_toks_dev, self.pool.state = \
                            self._decode_sampled(
                                self.params, self._last_toks_dev,
                                self.pool.state, limits_dev, samp_dev,
                                pos_dev)
                    else:
                        stack, self._last_toks_dev, self.pool.state = \
                            self._decode(self.params, self._last_toks_dev,
                                         self.pool.state, limits_dev)
                self._fence(stack)
            if self.paged:
                # host positions are deterministic once planned — advance
                # now so the next plan overlaps the in-flight device step
                self.pool.advance(steps=plan.limits)
            for slot, _ in plan.rows:
                self._slot_pos[slot] += plan.limits[slot]
        # speculative verifies: one batched fixed-width forward covering
        # every drafted slot this cycle ([last token, drafts, pad] per
        # row), after the decode scan so the donated page buffer threads
        # through in dispatch order.  The row count pads to the next
        # power of two (a handful of compiles per engine) with inert
        # rows: table 0 routes writes to the trash page, n_valid 0 masks
        # them, drafts -1 auto-reject.  Two host uploads total — the
        # token rows and one packed int32 matrix carrying
        # [page table | start | n_valid | sampling | drafts] per row.
        specs: Optional[Tuple[List[_SpecPlan], jax.Array, jax.Array]] = None
        if plan.specs:
            width = cfg.spec_tokens + 1
            tw = self.pool.table_width
            n = len(plan.specs)
            n_pad = 1 << (n - 1).bit_length()
            toks = np.zeros((n_pad, width), np.int32)
            packed = np.zeros((n_pad, tw + 2 + PACKED_WIDTH + width - 1),
                              np.int32)
            packed[:, tw + 2 + PACKED_WIDTH:] = -1
            total = 0
            for i, sp in enumerate(plan.specs):
                toks[i, 0] = sp.req.tokens[-1]
                toks[i, 1:1 + sp.m] = sp.drafts
                packed[i, :tw] = self.pool.tables[sp.slot]
                packed[i, tw] = sp.start
                packed[i, tw + 1] = sp.m + 1
                packed[i, tw + 2:tw + 2 + PACKED_WIDTH] = \
                    pack_params(sp.req.sampling)
                packed[i, tw + 2 + PACKED_WIDTH:
                       tw + 2 + PACKED_WIDTH + sp.m] = sp.drafts
                total += sp.m + 1
            with tr.span("verify.device", tokens=total, rows=n):
                emit, nacc, self.pool.pages = self._verify(
                    self.params, jnp.asarray(toks), self.pool.pages,
                    jnp.asarray(packed))
                self._fence(emit)
            for sp in plan.specs:
                # optimistic host advance over the whole drafted span;
                # retire rewinds past the first mismatch
                self.pool.advance(steps={sp.slot: sp.m + 1})
                self._slot_pos[sp.slot] += sp.m + 1
                self._spec_wait.add(sp.slot)
                self._pending[sp.req.rid] = (self._pending.get(sp.req.rid, 0)
                                             + sp.m + 1)
            specs = (list(plan.specs), emit, nacc)
        for rid, _, _ in overrides:
            self._pending[rid] = self._pending.get(rid, 0) + 1
        for slot, rid in plan.rows:
            self._pending[rid] = self._pending.get(rid, 0) + plan.limits[slot]
        if not overrides and stack is None and specs is None:
            return None
        return _InFlight(overrides, plan.rows, plan.limits, stack,
                         cfg.decode_steps, specs)

    # ------------------------------------------------------------------
    # Phase 3: retire (materialise the previous cycle; emit in sync order)
    # ------------------------------------------------------------------

    def _dec_pending(self, rid: int, n: int) -> None:
        if n <= 0:
            return
        left = self._pending.get(rid, 0) - n
        if left > 0:
            self._pending[rid] = left
        else:
            self._pending.pop(rid, None)

    def _retire(self, inf: _InFlight, stream: Optional[StreamFn]) -> None:
        stack = np.asarray(inf.stack) if inf.stack is not None else None
        emitted: List[int] = []
        for rid, slot, tok in inf.overrides:
            if rid in self.results:
                continue
            req = self.requests[rid]
            emitted.append(rid)
            if self._emit(req, int(tok), stream):
                self._finalize(slot, req)
        # decode rows emit step-major / slot-minor — the synchronous
        # engine's per-step completion sweep order.  Rows past a slot's
        # budget or past an EOS cut (``rid in results``) are speculative
        # device output and are dropped here.
        for k in range(inf.n_steps):
            for slot, rid in inf.rows:
                if k >= inf.limits.get(slot, 0) or rid in self.results:
                    continue
                req = self.requests[rid]
                emitted.append(rid)
                self.metrics.record_decode_token()
                if self._emit(req, int(stack[k, slot]), stream):
                    self._finalize(slot, req)
        # speculative verifies: sync the whole cycle's accept counts and
        # emitted rows in one host transfer each, rewind each slot past
        # its first mismatch (freeing over-allocated tail pages) and emit
        # accepted drafts + the correction token — exactly the tokens
        # sequential decode would have produced, just computed in one
        # batched forward instead of sum(nacc + 1) steps
        if inf.specs is not None:
            sps, emit_dev, nacc_dev = inf.specs
            emit_all = np.asarray(emit_dev)
            nacc_all = np.asarray(nacc_dev)
            for i, sp in enumerate(sps):
                req, slot = sp.req, sp.slot
                self._spec_wait.discard(slot)
                self._dec_pending(req.rid, sp.m + 1)
                nacc = int(nacc_all[i])
                self.metrics.record_spec(sp.m, nacc)
                if (self.pool.owner.get(slot) != req.rid
                        or req.rid in self.results):
                    continue    # defensive: spec slots are never preempted
                emit = emit_all[i]
                new_pos = sp.start + 1 + nacc   # last accepted index
                self.pool.rewind(slot, new_pos)
                self._slot_pos[slot] = new_pos + 1
                self._last_toks_dev = self._set_tok(
                    self._last_toks_dev, slot, int(emit[nacc]))
                for j in range(nacc + 1):
                    emitted.append(req.rid)
                    self.metrics.record_decode_token()
                    if self._emit(req, int(emit[j]), stream):
                        self._finalize(slot, req)
                        break
        # ghost hygiene: a victim preempted after this cycle was submitted
        # had its ITL baseline dropped by the preemption — the emissions
        # above re-seeded it, so drop it again to keep the requeue ->
        # resume gap out of inter-token latency
        owned = set(self.pool.owner.values())
        for rid in emitted:
            if rid not in self.results and rid not in owned:
                self.metrics.drop_itl_baseline(rid)
        # symmetric pending release (EOS cuts don't change what was
        # dispatched, so the decrement mirrors the submit-side increment)
        for rid, _, _ in inf.overrides:
            self._dec_pending(rid, 1)
        for slot, rid in inf.rows:
            self._dec_pending(rid, inf.limits.get(slot, 0))

    # ------------------------------------------------------------------
    # Preemption helpers (shared by plan-phase policies)
    # ------------------------------------------------------------------

    def _preempt(self, slot: int):
        """Evict a running request and put it back at the queue head; its
        emitted tokens fold into the resume prompt (greedy decode, so the
        eventual output is unchanged).  A victim caught mid-prefill simply
        restarts its suffix on resume (its shared prefix pages stay cached,
        so the lost work is the uncommitted chunks only).  A victim with
        un-retired tokens in flight stays un-admittable until they emit
        (``_pending`` / ``skip_rids``)."""
        victim = self.requests[self.pool.owner[slot]]
        self._prefilling.pop(slot, None)
        self.pool.evict(slot)
        self.scheduler.requeue(victim)
        self.metrics.record_preemption(victim.rid)
        # close whichever lifecycle span the victim had open (end() of a
        # not-open span is a silent no-op) and put it back to "queued"
        rt = request_track(victim.rid)
        self.tracer.end("prefill", track=rt, preempted=True)
        self.tracer.end("decode", track=rt, preempted=True)
        self.tracer.instant("request.preempt", track=rt, rid=victim.rid,
                            preemptions=victim.preempted)
        self.tracer.begin("queued", track=rt, resumed=True)

    def _relieve_pressure(self, prefer_not: Optional[int] = None) -> bool:
        """Preempt the lowest-priority, youngest running request to free
        pages — preferring a victim other than ``prefer_not`` (a slot
        mid-prefill that triggered the pressure preempts itself only when
        it is the lone tenant).  Recency is judged by rid (monotone
        submission order): ``arrival_seq`` goes negative on requeue, so it
        cannot rank original arrivals.  Slots with a speculative verify in
        flight are never victims (their retire rewinds pool state in
        place); returns False when that leaves no candidate."""
        candidates = [s for s in self.pool.active_slots
                      if s != prefer_not and s not in self._spec_wait]
        if not candidates:
            candidates = [s for s in self.pool.active_slots
                          if s not in self._spec_wait]
        if not candidates:
            return False
        self._preempt(max(
            candidates,
            key=lambda s: (-self.requests[self.pool.owner[s]].priority,
                           self.pool.owner[s])))
        return True

    # ------------------------------------------------------------------
    # The cycle
    # ------------------------------------------------------------------

    def step(self, stream: Optional[StreamFn] = None) -> bool:
        """One engine cycle; returns True while work remains.

        ``pipeline_depth=2``: plan and submit cycle N+1, then retire cycle
        N — the host plans against the in-flight device step and the
        device is never idle waiting for planning.  ``pipeline_depth=1``:
        retire what was just submitted (synchronous semantics).

        Traced (``ServeConfig(trace=True)``), the cycle decomposes into
        the section spans of ``repro.obs.export.STEP_SECTIONS``
        (``step.plan`` / ``step.submit`` / ``step.retire`` tile the
        enclosing ``step`` span) and the device calls are fenced with
        ``block_until_ready`` so host vs device time separates.
        Untraced, every ``with tracer.span(...)`` is the shared no-op
        context manager and no fence runs.
        """
        tr = self.tracer
        with tr.span("step"):
            with tr.span("step.plan"):
                plan = self._plan_cycle()
            with tr.span("step.draft", rows=len(plan.rows)):
                self._plan_spec(plan)
            with tr.span("step.submit"):
                nxt = self._submit(plan)
                prev, self._inflight = self._inflight, nxt
                if prev is not None or nxt is not None:
                    tr.counter(INFLIGHT_COUNTER,
                               int(prev is not None) + int(nxt is not None))
            if self.cfg.pipeline_depth == 1:
                # prev is always None at depth 1 — retire this very cycle
                prev, self._inflight = self._inflight, None
            with tr.span("step.retire", pending=prev is not None):
                if prev is not None:
                    self._retire(prev, stream)
                    tr.counter(INFLIGHT_COUNTER,
                               int(self._inflight is not None))
        return self.busy

    def run(self, stream: Optional[StreamFn] = None) -> Dict[int, List[int]]:
        """Drive the loop until queue, slots and pipeline drain; returns
        rid -> tokens."""
        while self.step(stream):
            pass
        return dict(self.results)

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def save_trace(self, path: str) -> Optional[str]:
        """Write the tracer's ring buffer as a Perfetto-loadable Chrome
        trace JSON (``{"traceEvents": [...]}``); None when the engine runs
        untraced (``ServeConfig(trace=False)`` — nothing was recorded)."""
        if not self.tracer.enabled:
            return None
        return write_chrome_trace(self.tracer, path)

    # ------------------------------------------------------------------
    # Convenience: serve a closed batch of prompts
    # ------------------------------------------------------------------

    def generate(self, prompts, max_new_tokens: Optional[int] = None,
                 stream: Optional[StreamFn] = None,
                 sampling=None) -> List[List[int]]:
        """Submit ``prompts`` (list of token lists) and run to completion.

        ``sampling`` is one ``SamplingParams`` applied to every prompt, or
        a per-prompt list (None entries mean greedy).

        A closed batch larger than ``max_queue`` is fed with backpressure:
        when the admission queue is full the engine cycles until it drains
        (running requests finish and free slots), then keeps submitting —
        no request of a closed batch is ever shed.
        """
        if sampling is None or isinstance(sampling, SamplingParams):
            per_req = [sampling] * len(prompts)
        else:
            per_req = list(sampling)
            if len(per_req) != len(prompts):
                raise ValueError(
                    f"sampling list length {len(per_req)} != "
                    f"{len(prompts)} prompts")
        rids = []
        for p, sp in zip(prompts, per_req):
            while self.scheduler.depth() >= self.cfg.max_queue:
                self.step(stream)
            rid = self.submit(p, max_new_tokens, sampling=sp)
            if rid is None:
                raise RuntimeError("queue admitted past max_queue")
            rids.append(rid)
        out = self.run(stream)
        return [out[r] for r in rids]
