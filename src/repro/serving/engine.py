"""Continuous-batching serving engine.

The user supplies a model config (whose registry bundle declares the
``ServeContract`` / ``PagedServeContract`` / ``PagedPrefillContract`` decode
paths — the engine dispatches on ``bundle.capabilities()``, never on
``is None`` probes); the engine supplies everything the paper's transparency
principle says the runtime should own: request admission, slot-level
KV-cache management, prefix-cache page sharing, prefill/decode interleaving,
and mesh sharding.  A sequential "one request at a time" mental model in,
heavy traffic out.  User scripts reach this through ``repro.api``'s
``Session.serve`` / ``Session.generate``.

Event loop (one ``step()`` = one cycle):

  1. preemption  — under the ``priority`` policy, evict low-priority slots
                   for strictly-higher-priority waiters (state re-prefilled
                   on resume; emitted tokens are kept).
  2. admission   — start up to ``max_prefills_per_step`` waiting requests.
                   On the paged path a request first maps every page of its
                   prompt that the prefix cache already holds (read-only,
                   refcounted; copy-on-write when a partially reused page
                   must be written) — only the uncached suffix is prefilled.
  3. chunked prefill — each admitted-but-unfinished request runs one
                   ``prefill_chunk_tokens``-sized chunk of its suffix per
                   cycle, so a long prompt's prefill interleaves with decode
                   instead of stalling running streams' inter-token latency.
  4. decode      — ``decode_steps`` batched decode steps over the *fixed*
                   slot pool: decode compiles exactly once because the
                   batch shape never changes; slots still prefilling are
                   masked to the trash page for the step.
  5. completion  — finished slots (token budget or EOS) are evicted
                   individually; their neighbours never notice.

Prefill compiles are bounded: prompt/chunk lengths are padded to power-of-
two buckets with masked tails (``ServeConfig.prefill_bucket``), so the jit
cache holds O(log max_seq_len) entries instead of one per distinct prompt
length (``metrics.compile_count`` tracks traces).  Recurrent families
(whose state a masked tail would corrupt) keep exact-length prefills.

KV memory is page-granular for every family with a ``KVLayout``
(``repro.serving.layouts``): per-head k/v pages for full attention,
ring-wrapped window pages for sliding-window/local attention (a slot holds
at most ``window`` tokens, pages rotating out of the window free or park
in the prefix LRU), and latent ckv/krope pages for MLA.  Pages are
allocated lazily as each request's position crosses page boundaries and
freed on eviction, so cache bytes held track actual sequence lengths
instead of ``max_batch x max_seq_len``, and ``num_pages`` may
oversubscribe — on page pressure the engine preempts the youngest request
(resume re-prefills; emitted tokens are kept, so greedy output is
unchanged — and typically re-prefills *from the prefix cache*, since its
own blocks were committed on first admission).  Recurrent families
(RG-LRU / RWKV: O(1) state per slot — nothing to page) fall back to the
slotted pool; ``ServeConfig.kv_layout`` forces either layout.

Greedy (argmax) decoding — chosen so batched serving is *token-identical*
to an unbatched sequential decode of each request, the serving analogue of
the paper's Fig. 7 equivalence claim (tested in tests/test_serving.py and,
for prefix hits, tests/test_prefix_cache.py).

Mesh transparency: pass a ``MeshConfig`` and the engine places parameters
via the same logical-axis rules as ``TransparentTrainer`` (tensor-parallel
decode over "model") and shards the slot pool over the data axes
(data-parallel replica serving).  No user code changes — the config *is*
the deployment.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MeshConfig, ModelConfig, ServeConfig
from repro.models import common, registry
from repro.obs import (NULL_TRACER, Tracer, request_track,
                       write_chrome_trace)
from repro.serving.kvcache import SlotKVCachePool
from repro.serving.metrics import ServingMetrics
from repro.serving.paged import PagedKVCachePool
from repro.serving.scheduler import Request, Scheduler

P = jax.sharding.PartitionSpec

# stream callback: (request_id, token, done) -> None
StreamFn = Callable[[int, int, bool], None]

#: smallest prefill bucket — below this the pad overhead beats the compile
_MIN_BUCKET = 16


def bucket_len(n: int, cap: int, *, floor: int = _MIN_BUCKET) -> int:
    """Smallest power-of-two >= n (floored at ``floor``), clamped to the
    cache capacity ``cap`` — the final bucket is the capacity itself, so
    every admissible length lands in O(log cap) distinct shapes."""
    assert 1 <= n <= cap, (n, cap)
    return min(max(floor, 1 << (n - 1).bit_length()), cap)


class _PrefillJob:
    """Host-side progress of one request's chunked suffix prefill."""

    __slots__ = ("req", "prompt", "done")

    def __init__(self, req: Request, prompt: Tuple[int, ...], done: int):
        self.req = req
        self.prompt = prompt
        self.done = done                  # tokens already cached


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig,
                 serve_cfg: Optional[ServeConfig] = None, *,
                 params=None, mesh_cfg: Optional[MeshConfig] = None,
                 seed: int = 0, clock=None):
        self.model_cfg = model_cfg
        # (ServeConfig self-validates at construction — no re-check here)
        self.cfg = serve_cfg or ServeConfig()
        # observability: one engine-owned Tracer (ServeConfig(trace=True))
        # threaded through scheduler, pool and metrics; NULL_TRACER keeps
        # every emit a no-op attribute call when tracing is off
        self.tracer = (Tracer(capacity=self.cfg.trace_capacity,
                              meta={"model": model_cfg.name,
                                    "family": model_cfg.family,
                                    "backend": jax.default_backend()})
                       if self.cfg.trace else NULL_TRACER)
        # traced mode fences device calls (block_until_ready) so host vs
        # device time separates; untraced, dispatch stays fully async
        self._fence = (jax.block_until_ready if self.tracer.enabled
                       else (lambda x: x))
        self.bundle = registry.build(model_cfg)
        caps = self.bundle.capabilities()
        if "serve" not in caps:
            raise ValueError(
                f"{model_cfg.name} ({model_cfg.family}) has no serving "
                "decode-path contract (ServeContract); encdec/vlm "
                "frontends need per-request modality inputs — see ROADMAP")

        # -- mesh placement (config-selected, transparent to callers) -----
        self.mesh = None
        dp_axes, dp_total, model_size = (), 1, 1
        if mesh_cfg is not None:
            from repro.launch import mesh as mesh_mod
            mesh_cfg.validate()
            self.mesh = mesh_mod.build_mesh(mesh_cfg)
            dp_axes = mesh_cfg.dp_axes
            dp_total = mesh_mod.dp_size(mesh_cfg)
            model_size = mesh_mod.model_size(mesh_cfg)
            rules = common.rules_for(mesh_cfg, model_cfg)
            param_sh = common.logical_to_mesh(self.bundle.specs, self.mesh,
                                              rules)
        if params is None:
            params = self.bundle.init_params(jax.random.PRNGKey(seed))
        if self.mesh is not None:
            params = jax.device_put(params, param_sh)
        self.params = params

        # -- KV pool: page-granular when the family has a KVLayout (the
        # layout seam is the capability authority: per-head k/v, latent, or
        # ring-wrapped window pages; recurrent families' O(1) state has no
        # layout and stays slot-granular)
        self.layout = self.bundle.kv_layout
        self.paged = ("paged_serve" in caps
                      and self.cfg.kv_layout != "slotted")
        if self.cfg.kv_layout == "paged" and not self.paged:
            raise ValueError(
                f"{model_cfg.name} ({model_cfg.family}/{model_cfg.attn_kind})"
                " has no paged decode path (PagedServeContract / KVLayout); "
                "recurrent families' O(1) state uses the slotted pool "
                "(kv_layout='auto')")
        # prefix-cache page sharing + chunked prefill need the paged
        # prefill contract (engine writes pages in place, no state scatter)
        self._prefix_path = self.paged and "prefix_serve" in caps
        # masked-tail power-of-two bucketing of whole-prompt prefills
        self._bucket_slotted = (self.cfg.prefill_bucket
                                and "bucketed_prefill" in caps)
        if self.paged:
            # windowed families: a page must fit (and tile) the window —
            # fail here with one ServeConfig-level error, not deep in the
            # pool or a kernel
            self.cfg.check_window(self.layout.window)
            self.pool = PagedKVCachePool(
                self.cfg.max_batch, self.cfg.page_size, self.cfg.max_seq_len,
                lambda: self.bundle.init_decode_state(1, self.cfg.page_size),
                num_pages=self.cfg.num_pages, mesh=self.mesh,
                model_size=model_size, layout=self.layout,
                enable_prefix_cache=(self.cfg.enable_prefix_cache
                                     and self._prefix_path),
                tracer=self.tracer)
            self._cache_len = self.pool.padded_len   # page-multiple prefill
            # ring chunks are capped at the window: a longer write-then-
            # attend chunk would wrap onto cells its own queries still need
            self._chunk_cap = self.layout.max_chunk_tokens(
                self.pool.padded_len)
        else:
            self.pool = SlotKVCachePool(
                self.cfg.max_batch,
                lambda: self.bundle.init_decode_state(1, self.cfg.max_seq_len),
                mesh=self.mesh, dp_axes=dp_axes, dp_total=dp_total,
                model_size=model_size)
            self._cache_len = self.cfg.max_seq_len

        self.scheduler = Scheduler(self.cfg, tracer=self.tracer)
        self.metrics = ServingMetrics(clock, tracer=self.tracer)
        self.requests: Dict[int, Request] = {}
        self.results: Dict[int, List[int]] = {}
        self._rid = itertools.count()
        self._last_tokens = np.zeros((self.cfg.max_batch,), np.int32)
        self._prefilling: Dict[int, _PrefillJob] = {}   # slot -> job
        self.prefill_compiles = 0         # lifetime (metrics.reset survives)

        # -- compiled entry points -----------------------------------------
        # prefill compiles are counted at trace time: a wrapper bump runs
        # once per new jit cache entry, which is exactly the XLA compile
        # count the bucketing is there to bound
        def _counted(fn):
            def wrapped(*a, **k):
                self.prefill_compiles += 1
                self.metrics.record_prefill_compile()
                # a[1] is the token operand: its (traced) shape is the
                # bucket this compile covers
                self.tracer.instant("prefill.compile",
                                    shape=list(a[1].shape))
                return fn(*a, **k)
            return wrapped

        # whole-prompt prefill: one jit object; XLA caches per
        # (bucket_len | prompt_len, cache_len) pair
        self._prefill = jax.jit(_counted(self.bundle.serve_prefill_fn),
                                static_argnames=("cache_len",))

        decode_fn = self.bundle.decode_fn
        paged_decode_fn = self.bundle.paged_decode_fn
        paged_prefill_fn = self.bundle.paged_prefill_fn

        def _decode_step(params, toks, pool_state):
            """toks [slots,1,1] + pool -> (greedy next token [slots], pool)."""
            logits, new_state = jax.vmap(decode_fn, in_axes=(None, 0, 0))(
                params, toks, pool_state)
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            return nxt, new_state

        # backend-selected like core/allreduce: the Pallas paged-attention
        # kernel on TPU (HBM traffic ~ pages held), traced ref gather on CPU
        paged_kernel = jax.default_backend() == "tpu"

        def _decode_step_paged(params, toks, pages, table, pos):
            """toks [slots,1] against the shared page pool (one batched call
            — no vmap: all slots gather from the same pages)."""
            logits, new_pages = paged_decode_fn(
                params, toks, {"pages": pages, "page_table": table,
                               "pos": pos}, use_pallas=paged_kernel)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_pages

        def _prefill_chunk(params, toks, pages, table, start, n_valid):
            """One request's suffix chunk straight into the page pool
            (pages donated; the scalar/table operands are tiny uploads)."""
            return paged_prefill_fn(params, toks,
                                    {"pages": pages, "page_table": table,
                                     "start": start, "n_valid": n_valid})

        if self.mesh is not None:
            slots = self.cfg.max_batch
            tok_axis = (tuple(dp_axes) if dp_total > 1
                        and slots % dp_total == 0 else None)

            def ns(spec):
                return jax.sharding.NamedSharding(self.mesh, spec)

            if self.paged:
                self._decode = jax.jit(
                    _decode_step_paged,
                    in_shardings=(param_sh, ns(P(None, None)),
                                  self.pool.shardings,
                                  ns(P(None, None)), ns(P(None))),
                    out_shardings=(ns(P()), self.pool.shardings),
                    donate_argnums=(2,))
                if self._prefix_path:
                    self._paged_prefill = jax.jit(
                        _counted(_prefill_chunk),
                        in_shardings=(param_sh, ns(P(None, None)),
                                      self.pool.shardings, ns(P(None)),
                                      ns(P()), ns(P())),
                        out_shardings=(ns(P(None, None)),
                                       self.pool.shardings),
                        donate_argnums=(2,))
            else:
                self._decode = jax.jit(
                    _decode_step,
                    in_shardings=(param_sh,
                                  ns(P(tok_axis, None, None)),
                                  self.pool.shardings),
                    out_shardings=(ns(P()), self.pool.shardings),
                    donate_argnums=(2,))
        elif self.paged:
            self._decode = jax.jit(_decode_step_paged, donate_argnums=(2,))
            if self._prefix_path:
                self._paged_prefill = jax.jit(_counted(_prefill_chunk),
                                              donate_argnums=(2,))
        else:
            self._decode = jax.jit(_decode_step, donate_argnums=(2,))

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               priority: int = 0, deadline: Optional[float] = None
               ) -> Optional[int]:
        """Queue one request.  Returns its id, or None when the admission
        queue is full (caller sheds load / retries)."""
        prompt = tuple(int(t) for t in prompt)
        max_new = (self.cfg.max_new_tokens if max_new_tokens is None
                   else max_new_tokens)
        if max_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new}")
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.cfg.max_seq_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"slot capacity max_seq_len={self.cfg.max_seq_len}")
        rid = next(self._rid)
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                      priority=priority, deadline=deadline)
        if not self.scheduler.submit(req):
            self.metrics.record_reject()
            return None
        self.requests[rid] = req
        self.metrics.record_submit(rid)
        self.tracer.begin("queued", track=request_track(rid),
                          prompt_tokens=len(prompt))
        return rid

    # ------------------------------------------------------------------
    # Event loop
    # ------------------------------------------------------------------

    @property
    def busy(self) -> bool:
        return bool(self.scheduler.depth() or self.pool.owner)

    def _emit(self, req: Request, token: int, stream: Optional[StreamFn]):
        first = not req.tokens
        req.tokens.append(token)
        if first:
            # a resumed preemptee keeps its tokens, so ``first`` is only
            # true on the genuine first emission (even if the request was
            # bounced at admission before ever running)
            self.metrics.record_first_token(req.rid)
        else:
            self.metrics.record_token(req.rid)
        done = self._finished(req, token)
        if stream is not None:
            stream(req.rid, token, done)
        return done

    def _finished(self, req: Request, token: int) -> bool:
        if self.cfg.eos_token >= 0 and token == self.cfg.eos_token:
            return True
        return len(req.tokens) >= req.max_new_tokens

    def _complete(self, slot: int, req: Request):
        self.pool.evict(slot)
        self.results[req.rid] = req.tokens
        self.metrics.record_completion(req.rid)
        rt = request_track(req.rid)
        self.tracer.end("decode", track=rt, tokens=len(req.tokens))
        self.tracer.instant("request.complete", track=rt, rid=req.rid,
                            tokens=len(req.tokens),
                            preempted=req.preempted)

    def _can_admit(self, prompt) -> bool:
        """Would the paged pool take this prompt right now (slot + pages,
        net of prefix-cache hits)?  Used by the priority policy's
        blocked-admission check only — actual admission goes straight
        through ``_admit``/``alloc_prefix`` (no double planning)."""
        return self.pool.can_admit_prompt(prompt) if self._prefix_path \
            else self.pool.can_admit(len(prompt))

    def _bucketed_prompt(self, prompt, cap: int):
        """(tokens [1, S], n_valid_or_None): pad to a power-of-two bucket
        when the family supports masked tails, else the exact length."""
        n = len(prompt)
        if not self._bucket_slotted:
            return jnp.asarray(np.asarray(prompt, np.int32)[None, :]), None
        toks = np.zeros((1, bucket_len(n, cap)), np.int32)
        toks[0, :n] = prompt
        return jnp.asarray(toks), n

    def _admit(self, req: Request, stream: Optional[StreamFn]) -> bool:
        """Place one request; False when the pool cannot take it right now
        (paged page shortage — the caller re-queues it, never drops it).
        The pool is the single admission authority: no pre-check re-plans
        the prompt, so each admission attempt hashes its blocks once."""
        prompt = req.resume_prompt()
        rt = request_track(req.rid)
        if self._prefix_path:
            # map cached prefix pages read-only; suffix prefills in chunks
            # (the first chunk runs this same cycle in _advance_prefills)
            out = self.pool.alloc_prefix(req.rid, prompt)
            if out is None:
                return False
            slot, cached = out
            if cached:
                self.metrics.record_prefix_hit(cached)
            self.tracer.end("queued", track=rt)
            self.tracer.begin("prefill", track=rt,
                              prompt_tokens=len(prompt),
                              prefix_hit_tokens=cached)
            self._prefilling[slot] = _PrefillJob(req, prompt, cached)
            return True
        if self.paged and not self.pool.can_admit(len(prompt)):
            # slot free but pages aren't: don't burn a prefill that
            # cannot be placed
            return False
        self.tracer.end("queued", track=rt)
        toks, n_valid = self._bucketed_prompt(prompt, self._cache_len)
        self.tracer.begin("prefill", track=rt, prompt_tokens=len(prompt),
                          bucket=int(toks.shape[1]))
        with self.tracer.span("prefill.device", tokens=len(prompt),
                              bucket=int(toks.shape[1])):
            if n_valid is None:
                logits, state = self._prefill(self.params, toks,
                                              cache_len=self._cache_len)
            else:
                logits, state = self._prefill(self.params, toks,
                                              cache_len=self._cache_len,
                                              n_valid=jnp.asarray(n_valid,
                                                                  jnp.int32))
            self._fence(logits)
        self.metrics.record_prefill(len(prompt))
        if self.paged:
            slot = self.pool.insert(req.rid, state, n_tokens=len(prompt))
        else:
            slot = self.pool.insert(req.rid, state)
        if slot is None:
            raise RuntimeError("admission with no free slot")
        token = int(jnp.argmax(logits[0]))
        self._last_tokens[slot] = token
        self.tracer.end("prefill", track=rt)
        self.tracer.begin("decode", track=rt)
        if self._emit(req, token, stream):
            self._complete(slot, req)
        return True

    def _advance_prefills(self, stream: Optional[StreamFn]):
        """Run one suffix chunk per prefilling slot (chunked prefill): each
        cycle a long prompt advances ``prefill_chunk_tokens`` tokens while
        every already-running stream keeps decoding in the same cycle.
        Ring (windowed) layouts cap chunks at the window and rotate /
        copy-on-write the cells each chunk will overwrite first."""
        for slot in sorted(self._prefilling):
            job = self._prefilling.get(slot)
            if job is None:                 # preempted by an earlier slot's
                continue                    # pressure relief this cycle
            remaining = len(job.prompt) - job.done
            chunk = min(remaining, self.cfg.prefill_chunk_tokens
                        or self._chunk_cap, self._chunk_cap)
            if not self.pool.prepare_chunk(slot, job.done,
                                           job.done + chunk - 1):
                # page pressure mid-prefill (ring rotation needed a COW or
                # fresh page): relieve it like decode growth does — preempt
                # the lowest-priority youngest other request, else bounce
                # this one back to the queue and retry next cycle
                self._relieve_pressure(prefer_not=slot)
                if slot not in self._prefilling or \
                        not self.pool.prepare_chunk(slot, job.done,
                                                    job.done + chunk - 1):
                    continue
            width = (bucket_len(chunk, self.pool.padded_len)
                     if self.cfg.prefill_bucket else chunk)
            toks = np.zeros((1, width), np.int32)
            toks[0, :chunk] = job.prompt[job.done:job.done + chunk]
            rt = request_track(job.req.rid)
            with self.tracer.span("prefill.chunk", track=rt, chunk=chunk,
                                  bucket=width, start=job.done):
                with self.tracer.span("prefill.device", tokens=chunk,
                                      bucket=width):
                    logits, self.pool.pages = self._paged_prefill(
                        self.params, jnp.asarray(toks), self.pool.pages,
                        jnp.asarray(self.pool.tables[slot]),
                        jnp.asarray(job.done, jnp.int32),
                        jnp.asarray(chunk, jnp.int32))
                    self._fence(logits)
            self.metrics.record_prefill(chunk)
            job.done += chunk
            # register fully-written blocks right away: requests admitted
            # while this one still chunks can already share its prefix
            self.pool.commit_prefix(slot, job.prompt[:job.done])
            if job.done < len(job.prompt):
                continue
            del self._prefilling[slot]
            token = int(jnp.argmax(logits[0]))
            self._last_tokens[slot] = token
            self.tracer.end("prefill", track=rt)
            self.tracer.begin("decode", track=rt)
            if self._emit(job.req, token, stream):
                self._complete(slot, job.req)

    def _preempt(self, slot: int):
        """Evict a running request and put it back at the queue head; its
        emitted tokens fold into the resume prompt (greedy decode, so the
        eventual output is unchanged).  A victim caught mid-prefill simply
        restarts its suffix on resume (its shared prefix pages stay cached,
        so the lost work is the uncommitted chunks only)."""
        victim = self.requests[self.pool.owner[slot]]
        self._prefilling.pop(slot, None)
        self.pool.evict(slot)
        self.scheduler.requeue(victim)
        self.metrics.record_preemption(victim.rid)
        # close whichever lifecycle span the victim had open (end() of a
        # not-open span is a silent no-op) and put it back to "queued"
        rt = request_track(victim.rid)
        self.tracer.end("prefill", track=rt, preempted=True)
        self.tracer.end("decode", track=rt, preempted=True)
        self.tracer.instant("request.preempt", track=rt, rid=victim.rid,
                            preemptions=victim.preempted)
        self.tracer.begin("queued", track=rt, resumed=True)

    def _relieve_pressure(self, prefer_not: Optional[int] = None):
        """Preempt the lowest-priority, youngest running request to free
        pages — preferring a victim other than ``prefer_not`` (a slot
        mid-prefill that triggered the pressure preempts itself only when
        it is the lone tenant).  Recency is judged by rid (monotone
        submission order): ``arrival_seq`` goes negative on requeue, so it
        cannot rank original arrivals."""
        candidates = [s for s in self.pool.active_slots if s != prefer_not]
        if not candidates:
            candidates = self.pool.active_slots
        self._preempt(max(
            candidates,
            key=lambda s: (-self.requests[self.pool.owner[s]].priority,
                           self.pool.owner[s])))

    def _grow_pages(self):
        """Paged pool: make every decoding slot able to write its next token
        (lazy growth; ring layouts rotate / COW the cell being wrapped
        into); on page pressure, preempt until the rest fit — even a
        non-starving victim is evicted, since its freed pages rebalance to
        the earlier arrivals."""
        while True:
            starved = self.pool.ensure_decode_capacity(
                skip=self._prefilling.keys())
            if not starved:
                return
            self._relieve_pressure()

    def _decodable(self) -> bool:
        return any(s not in self._prefilling for s in self.pool.owner)

    def step(self, stream: Optional[StreamFn] = None) -> bool:
        """One engine cycle; returns True while work remains.

        Traced (``ServeConfig(trace=True)``), the cycle decomposes into the
        section spans of ``repro.obs.export.STEP_SECTIONS`` — they tile the
        enclosing ``step`` span, and the device calls are fenced with
        ``block_until_ready`` so host vs device time separates.  Untraced,
        every ``with tracer.span(...)`` is the shared no-op context manager
        and no fence runs.
        """
        cfg = self.cfg
        tr = self.tracer
        with tr.span("step"):
            self._step_body(stream, cfg, tr)
        return self.busy

    def _step_body(self, stream: Optional[StreamFn], cfg: ServeConfig,
                   tr) -> None:
        # 1. preemption (priority policy only): fires when admission is
        # blocked — no free slot, or (paged) too few free pages for the
        # most urgent waiter's prompt (prefix-cache hits shrink that need)
        with tr.span("preempt"):
            if cfg.policy == "priority" and self.scheduler.depth():
                head = self.scheduler.peek()
                blocked = (self.pool.free_slots == 0
                           or (self.paged
                               and not self._can_admit(
                                   head.resume_prompt())))
                if blocked:
                    running = {s: self.requests[r]
                               for s, r in self.pool.owner.items()}
                    for slot, _ in self.scheduler.preemption(running):
                        self._preempt(slot)
        # 2. admission: map prefix pages / prefill into free slots.  When
        # the pool declines (slot free but pages aren't), wait for running
        # work to finish: EVERY not-yet-admitted popped request goes back
        # (reversed, so the head of the line ends up most negative = first)
        # — head-of-line blocking, never a silent drop.
        with tr.span("admit"):
            pending = self.scheduler.next_prefills(self.pool.free_slots)
            for i, req in enumerate(pending):
                if not self._admit(req, stream):
                    for r in reversed(pending[i:]):
                        self.scheduler.push_front(r)
                    break
        # 2b. chunked prefill: one chunk per mid-prefill slot per cycle
        with tr.span("prefill"):
            if self._prefilling:
                self._advance_prefills(stream)
        with tr.span("sample"):
            self.metrics.sample_queue_depth(self.scheduler.depth())
            self.metrics.sample_kv_bytes(self.pool.kv_bytes_held(),
                                         self.pool.kv_bytes_slotted())
        # 3. batched decode over the fixed pool
        for _ in range(cfg.decode_steps):
            if not self._decodable():
                break
            if self.paged:
                with tr.span("decode.host"):
                    self._grow_pages()
                    decodable = self._decodable()
                    if decodable:
                        # held pages peak right after growth (completion
                        # evictions come later in this iteration) — sample
                        # here so kv_bytes_peak sees the true high-water
                        # mark
                        self.metrics.sample_kv_bytes(
                            self.pool.kv_bytes_held(),
                            self.pool.kv_bytes_slotted())
                        table, pos = self.pool.decode_view(
                            mask_slots=tuple(self._prefilling))
                        toks = jnp.asarray(self._last_tokens[:, None])
                if not decodable:
                    break
                with tr.span("decode.device"):
                    nxt, self.pool.pages = self._decode(self.params, toks,
                                                        self.pool.pages,
                                                        table, pos)
                    self._fence(nxt)
                with tr.span("decode.host"):
                    self.pool.advance(skip=self._prefilling.keys())
            else:
                with tr.span("decode.host"):
                    toks = jnp.asarray(self._last_tokens.reshape(-1, 1, 1))
                with tr.span("decode.device"):
                    nxt, self.pool.state = self._decode(self.params, toks,
                                                        self.pool.state)
                    self._fence(nxt)
            # 4. completion swap-out (mid-prefill slots have no token yet)
            with tr.span("complete"):
                nxt = np.asarray(nxt)
                self._last_tokens = nxt.copy()
                for slot, rid in sorted(self.pool.owner.items()):
                    if slot in self._prefilling:
                        continue
                    req = self.requests[rid]
                    self.metrics.record_decode_token()
                    if self._emit(req, int(nxt[slot]), stream):
                        self._complete(slot, req)

    def run(self, stream: Optional[StreamFn] = None) -> Dict[int, List[int]]:
        """Drive the loop until queue and slots drain; returns rid -> tokens."""
        while self.step(stream):
            pass
        return dict(self.results)

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def save_trace(self, path: str) -> Optional[str]:
        """Write the tracer's ring buffer as a Perfetto-loadable Chrome
        trace JSON (``{"traceEvents": [...]}``); None when the engine runs
        untraced (``ServeConfig(trace=False)`` — nothing was recorded)."""
        if not self.tracer.enabled:
            return None
        return write_chrome_trace(self.tracer, path)

    # ------------------------------------------------------------------
    # Convenience: serve a closed batch of prompts
    # ------------------------------------------------------------------

    def generate(self, prompts, max_new_tokens: Optional[int] = None,
                 stream: Optional[StreamFn] = None) -> List[List[int]]:
        """Submit ``prompts`` (list of token lists) and run to completion.

        A closed batch larger than ``max_queue`` is fed with backpressure:
        when the admission queue is full the engine cycles until it drains
        (running requests finish and free slots), then keeps submitting —
        no request of a closed batch is ever shed.
        """
        rids = []
        for p in prompts:
            while self.scheduler.depth() >= self.cfg.max_queue:
                self.step(stream)
            rid = self.submit(p, max_new_tokens)
            if rid is None:
                raise RuntimeError("queue admitted past max_queue")
            rids.append(rid)
        out = self.run(stream)
        return [out[r] for r in rids]
