"""Continuous-batching serving engine.

The user supplies a model config (whose registry bundle declares the
``ServeContract`` / ``PagedServeContract`` decode paths — the engine
dispatches on ``bundle.capabilities()``, never on ``is None`` probes); the
engine supplies everything the paper's transparency principle says the
runtime should own: request admission, slot-level KV-cache management,
prefill/decode interleaving, and mesh sharding.  A sequential "one request
at a time" mental model in, heavy traffic out.  User scripts reach this
through ``repro.api``'s ``Session.serve`` / ``Session.generate``.

Event loop (one ``step()`` = one cycle):

  1. preemption  — under the ``priority`` policy, evict low-priority slots
                   for strictly-higher-priority waiters (state re-prefilled
                   on resume; emitted tokens are kept).
  2. admission   — prefill up to ``prefill_chunk`` waiting requests
                   (batch-of-1 prefills, jitted per prompt length) and
                   insert each resulting state into a free KV slot.
  3. decode      — ``decode_steps`` batched decode steps over the *fixed*
                   slot pool: decode compiles exactly once because the
                   batch shape never changes; per-slot ``pos``/``index``
                   leaves let slots run at ragged sequence positions.
  4. completion  — finished slots (token budget or EOS) are evicted
                   individually; their neighbours never notice.

KV memory is page-granular for the attention (lm) family (``PagedKVCachePool``
+ the paged-attention kernel family): pages are allocated lazily as each
request's position crosses page boundaries and freed on eviction, so cache
bytes held track actual sequence lengths instead of ``max_batch x
max_seq_len``, and ``num_pages`` may oversubscribe — on page pressure the
engine preempts the youngest request (resume re-prefills; emitted tokens are
kept, so greedy output is unchanged).  Recurrent families (RG-LRU / RWKV:
O(1) state per slot) and MLA / windowed attention fall back to the slotted
pool; ``ServeConfig.kv_layout`` forces either layout.

Greedy (argmax) decoding — chosen so batched serving is *token-identical*
to an unbatched sequential decode of each request, the serving analogue of
the paper's Fig. 7 equivalence claim (tested in tests/test_serving.py).

Mesh transparency: pass a ``MeshConfig`` and the engine places parameters
via the same logical-axis rules as ``TransparentTrainer`` (tensor-parallel
decode over "model") and shards the slot pool over the data axes
(data-parallel replica serving).  No user code changes — the config *is*
the deployment.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MeshConfig, ModelConfig, ServeConfig
from repro.models import common, registry
from repro.serving.kvcache import SlotKVCachePool
from repro.serving.metrics import ServingMetrics
from repro.serving.paged import PagedKVCachePool
from repro.serving.scheduler import Request, Scheduler

P = jax.sharding.PartitionSpec

# stream callback: (request_id, token, done) -> None
StreamFn = Callable[[int, int, bool], None]


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig,
                 serve_cfg: Optional[ServeConfig] = None, *,
                 params=None, mesh_cfg: Optional[MeshConfig] = None,
                 seed: int = 0, clock=None):
        self.model_cfg = model_cfg
        # (ServeConfig self-validates at construction — no re-check here)
        self.cfg = serve_cfg or ServeConfig()
        self.bundle = registry.build(model_cfg)
        caps = self.bundle.capabilities()
        if "serve" not in caps:
            raise ValueError(
                f"{model_cfg.name} ({model_cfg.family}) has no serving "
                "decode-path contract (ServeContract); encdec/vlm "
                "frontends need per-request modality inputs — see ROADMAP")

        # -- mesh placement (config-selected, transparent to callers) -----
        self.mesh = None
        dp_axes, dp_total, model_size = (), 1, 1
        if mesh_cfg is not None:
            from repro.launch import mesh as mesh_mod
            mesh_cfg.validate()
            self.mesh = mesh_mod.build_mesh(mesh_cfg)
            dp_axes = mesh_cfg.dp_axes
            dp_total = mesh_mod.dp_size(mesh_cfg)
            model_size = mesh_mod.model_size(mesh_cfg)
            rules = common.rules_for(mesh_cfg, model_cfg)
            param_sh = common.logical_to_mesh(self.bundle.specs, self.mesh,
                                              rules)
        if params is None:
            params = self.bundle.init_params(jax.random.PRNGKey(seed))
        if self.mesh is not None:
            params = jax.device_put(params, param_sh)
        self.params = params

        # -- KV pool: page-granular when the family declares the capability -
        # (kv_layout="auto": attention lm family pages; recurrent families'
        # O(1) state and MLA/windowed caches stay slot-granular)
        self.paged = ("paged_serve" in caps
                      and self.cfg.kv_layout != "slotted")
        if self.cfg.kv_layout == "paged" and not self.paged:
            raise ValueError(
                f"{model_cfg.name} ({model_cfg.family}/{model_cfg.attn_kind})"
                " has no paged decode path (PagedServeContract); recurrent, "
                "MLA, and windowed-attention families use the slotted pool "
                "(kv_layout='auto')")
        if self.paged:
            self.pool = PagedKVCachePool(
                self.cfg.max_batch, self.cfg.page_size, self.cfg.max_seq_len,
                lambda: self.bundle.init_decode_state(1, self.cfg.page_size),
                num_pages=self.cfg.num_pages, mesh=self.mesh,
                model_size=model_size)
            self._cache_len = self.pool.padded_len   # page-multiple prefill
        else:
            self.pool = SlotKVCachePool(
                self.cfg.max_batch,
                lambda: self.bundle.init_decode_state(1, self.cfg.max_seq_len),
                mesh=self.mesh, dp_axes=dp_axes, dp_total=dp_total,
                model_size=model_size)
            self._cache_len = self.cfg.max_seq_len

        self.scheduler = Scheduler(self.cfg)
        self.metrics = ServingMetrics(clock)
        self.requests: Dict[int, Request] = {}
        self.results: Dict[int, List[int]] = {}
        self._rid = itertools.count()
        self._last_tokens = np.zeros((self.cfg.max_batch,), np.int32)

        # -- compiled entry points -----------------------------------------
        # prefill: one jit object; XLA caches per (prompt_len, cache_len)
        self._prefill = jax.jit(self.bundle.serve_prefill_fn,
                                static_argnames=("cache_len",))

        decode_fn = self.bundle.decode_fn
        paged_decode_fn = self.bundle.paged_decode_fn

        def _decode_step(params, toks, pool_state):
            """toks [slots,1,1] + pool -> (greedy next token [slots], pool)."""
            logits, new_state = jax.vmap(decode_fn, in_axes=(None, 0, 0))(
                params, toks, pool_state)
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            return nxt, new_state

        # backend-selected like core/allreduce: the Pallas paged-attention
        # kernel on TPU (HBM traffic ~ pages held), traced ref gather on CPU
        paged_kernel = jax.default_backend() == "tpu"

        def _decode_step_paged(params, toks, pages, table, pos):
            """toks [slots,1] against the shared page pool (one batched call
            — no vmap: all slots gather from the same pages)."""
            logits, new_pages = paged_decode_fn(
                params, toks, {"pages": pages, "page_table": table,
                               "pos": pos}, use_pallas=paged_kernel)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_pages

        if self.mesh is not None:
            slots = self.cfg.max_batch
            tok_axis = (tuple(dp_axes) if dp_total > 1
                        and slots % dp_total == 0 else None)

            def ns(spec):
                return jax.sharding.NamedSharding(self.mesh, spec)

            if self.paged:
                self._decode = jax.jit(
                    _decode_step_paged,
                    in_shardings=(param_sh, ns(P(None, None)),
                                  self.pool.shardings,
                                  ns(P(None, None)), ns(P(None))),
                    out_shardings=(ns(P()), self.pool.shardings),
                    donate_argnums=(2,))
            else:
                self._decode = jax.jit(
                    _decode_step,
                    in_shardings=(param_sh,
                                  ns(P(tok_axis, None, None)),
                                  self.pool.shardings),
                    out_shardings=(ns(P()), self.pool.shardings),
                    donate_argnums=(2,))
        elif self.paged:
            self._decode = jax.jit(_decode_step_paged, donate_argnums=(2,))
        else:
            self._decode = jax.jit(_decode_step, donate_argnums=(2,))

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               priority: int = 0, deadline: Optional[float] = None
               ) -> Optional[int]:
        """Queue one request.  Returns its id, or None when the admission
        queue is full (caller sheds load / retries)."""
        prompt = tuple(int(t) for t in prompt)
        max_new = (self.cfg.max_new_tokens if max_new_tokens is None
                   else max_new_tokens)
        if max_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new}")
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.cfg.max_seq_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"slot capacity max_seq_len={self.cfg.max_seq_len}")
        rid = next(self._rid)
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                      priority=priority, deadline=deadline)
        if not self.scheduler.submit(req):
            self.metrics.record_reject()
            return None
        self.requests[rid] = req
        self.metrics.record_submit(rid)
        return rid

    # ------------------------------------------------------------------
    # Event loop
    # ------------------------------------------------------------------

    @property
    def busy(self) -> bool:
        return bool(self.scheduler.depth() or self.pool.owner)

    def _emit(self, req: Request, token: int, stream: Optional[StreamFn]):
        first = not req.tokens
        req.tokens.append(token)
        if first:
            # a resumed preemptee keeps its tokens, so ``first`` is only
            # true on the genuine first emission (even if the request was
            # bounced at admission before ever running)
            self.metrics.record_first_token(req.rid)
        else:
            self.metrics.record_token(req.rid)
        done = self._finished(req, token)
        if stream is not None:
            stream(req.rid, token, done)
        return done

    def _finished(self, req: Request, token: int) -> bool:
        if self.cfg.eos_token >= 0 and token == self.cfg.eos_token:
            return True
        return len(req.tokens) >= req.max_new_tokens

    def _complete(self, slot: int, req: Request):
        self.pool.evict(slot)
        self.results[req.rid] = req.tokens
        self.metrics.record_completion(req.rid)

    def _admit(self, req: Request, stream: Optional[StreamFn]):
        prompt = req.resume_prompt()
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        logits, state = self._prefill(self.params, toks,
                                      cache_len=self._cache_len)
        self.metrics.record_prefill(len(prompt))
        if self.paged:
            slot = self.pool.insert(req.rid, state, n_tokens=len(prompt))
        else:
            slot = self.pool.insert(req.rid, state)
        if slot is None:
            raise RuntimeError("admission with no free slot")
        token = int(jnp.argmax(logits[0]))
        self._last_tokens[slot] = token
        if self._emit(req, token, stream):
            self._complete(slot, req)

    def _preempt(self, slot: int):
        """Evict a running request and put it back at the queue head; its
        emitted tokens fold into the resume prompt (greedy decode, so the
        eventual output is unchanged)."""
        victim = self.requests[self.pool.owner[slot]]
        self.pool.evict(slot)
        self.scheduler.requeue(victim)
        self.metrics.record_preemption(victim.rid)

    def _grow_pages(self):
        """Paged pool: lazily allocate the page each slot's next token needs;
        on page pressure, preempt the lowest-priority, youngest *running*
        request until the rest fit — even a non-starving victim is evicted,
        since its freed pages rebalance to the earlier arrivals.  Recency is
        judged by rid (monotone submission order): ``arrival_seq`` goes
        negative on requeue, so it cannot rank original arrivals."""
        while True:
            starved = self.pool.ensure_decode_capacity()
            if not starved:
                return
            self._preempt(max(
                self.pool.active_slots,
                key=lambda s: (-self.requests[self.pool.owner[s]].priority,
                               self.pool.owner[s])))

    def step(self, stream: Optional[StreamFn] = None) -> bool:
        """One engine cycle; returns True while work remains."""
        cfg = self.cfg
        # 1. preemption (priority policy only): fires when admission is
        # blocked — no free slot, or (paged) too few free pages for the
        # most urgent waiter's prompt
        if cfg.policy == "priority" and self.scheduler.depth():
            head = self.scheduler.peek()
            blocked = (self.pool.free_slots == 0
                       or (self.paged and not self.pool.can_admit(
                           len(head.resume_prompt()))))
            if blocked:
                running = {s: self.requests[r]
                           for s, r in self.pool.owner.items()}
                for slot, _ in self.scheduler.preemption(running):
                    self._preempt(slot)
        # 2. admission: prefill into free slots, per-slot insertion
        pending = self.scheduler.next_prefills(self.pool.free_slots)
        for i, req in enumerate(pending):
            if (self.paged
                    and not self.pool.can_admit(len(req.resume_prompt()))):
                # slot free but pages aren't: wait for running work to
                # finish rather than burn a prefill that cannot be placed.
                # EVERY not-yet-admitted popped request goes back (reversed,
                # so the head of the line ends up most negative = first) —
                # head-of-line blocking, never a silent drop.
                for r in reversed(pending[i:]):
                    self.scheduler.push_front(r)
                break
            self._admit(req, stream)
        self.metrics.sample_queue_depth(self.scheduler.depth())
        self.metrics.sample_kv_bytes(self.pool.kv_bytes_held(),
                                     self.pool.kv_bytes_slotted())
        # 3. batched decode over the fixed pool
        for _ in range(cfg.decode_steps):
            if not self.pool.owner:
                break
            if self.paged:
                self._grow_pages()
                if not self.pool.owner:
                    break
                # held pages peak right after growth (completion evictions
                # come later in this iteration) — sample here so the
                # kv_bytes_peak metric sees the true high-water mark
                self.metrics.sample_kv_bytes(self.pool.kv_bytes_held(),
                                             self.pool.kv_bytes_slotted())
                table, pos = self.pool.decode_view()
                toks = jnp.asarray(self._last_tokens[:, None])
                nxt, self.pool.pages = self._decode(self.params, toks,
                                                    self.pool.pages, table,
                                                    pos)
                self.pool.advance()
            else:
                toks = jnp.asarray(self._last_tokens.reshape(-1, 1, 1))
                nxt, self.pool.state = self._decode(self.params, toks,
                                                    self.pool.state)
            nxt = np.asarray(nxt)
            self._last_tokens = nxt.copy()
            # 4. completion swap-out
            for slot, rid in sorted(self.pool.owner.items()):
                req = self.requests[rid]
                if self._emit(req, int(nxt[slot]), stream):
                    self._complete(slot, req)
        return self.busy

    def run(self, stream: Optional[StreamFn] = None) -> Dict[int, List[int]]:
        """Drive the loop until queue and slots drain; returns rid -> tokens."""
        while self.step(stream):
            pass
        return dict(self.results)

    # ------------------------------------------------------------------
    # Convenience: serve a closed batch of prompts
    # ------------------------------------------------------------------

    def generate(self, prompts, max_new_tokens: Optional[int] = None,
                 stream: Optional[StreamFn] = None) -> List[List[int]]:
        """Submit ``prompts`` (list of token lists) and run to completion.

        A closed batch larger than ``max_queue`` is fed with backpressure:
        when the admission queue is full the engine cycles until it drains
        (running requests finish and free slots), then keeps submitting —
        no request of a closed batch is ever shed.
        """
        rids = []
        for p in prompts:
            while self.scheduler.depth() >= self.cfg.max_queue:
                self.step(stream)
            rid = self.submit(p, max_new_tokens)
            if rid is None:
                raise RuntimeError("queue admitted past max_queue")
            rids.append(rid)
        out = self.run(stream)
        return [out[r] for r in rids]
