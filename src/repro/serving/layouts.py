"""Pluggable physical KV-page layouts — the runtime seam behind paged serving.

The paper's transparency principle says distribution (and serving) machinery
lives in the runtime, never in user code.  Until this module existed that
leaked: ``attn_kind == "full"`` probes scattered across the registry, the
page pool and the engine silently dropped MLA and sliding-window families
onto the slotted fallback, losing paged oversubscription and the prefix
cache.  A ``KVLayout`` describes everything the *physical* page format of a
family's decode cache needs:

  * ``leaves``  — which decode-state leaves the page pool tiles into pages
                  (per-head ``("k", "v")`` for GQA; latent ``("ckv",
                  "krope")`` for DeepSeek MLA — the pool itself never names
                  a leaf);
  * ``window``  — 0 for contiguous layouts (token ``t`` lives at page-table
                  column ``t // page_size`` forever); ``> 0`` for
                  *ring-wrapped* window pages: the table is a ring of
                  ``window // page_size`` cells, token ``t`` lives at cell
                  ``(t % window) // page_size``, and a cell's page is reused
                  in place as the sequence wraps — a slot holds at most
                  ``window`` tokens of K/V, matching the slotted ring
                  cache's memory exactly while keeping page-granular lazy
                  growth and prefix sharing;
  * ``kv_dtype`` — the *storage* dtype of the data leaves: ``"fp32"`` keeps
                  the family's native compute dtype; ``"int8"`` stores each
                  k/v row as int8 with a per-(page, offset, kv-head)
                  symmetric bfloat16 scale carried as an extra ``*_scale``
                  leaf
                  (``quantize_kv`` is the single quantizer — write paths
                  call it; the paged-attention kernels and their jnp
                  oracles multiply the scales back into the online-softmax
                  accumulation, never materializing fp pages).

``layout_for(cfg)`` is the single capability authority: the registry asks
it (instead of probing ``attn_kind`` strings) whether a family pages, and
the engine/pool take the returned layout as a constructor argument.  A new
cache format (quantized KV, hybrid local/global) plugs in by adding a
layout here — no pool/engine/registry surgery.  Quantized variants derive
from the base layouts via ``quantized_layout`` (the engine applies
``ServeConfig.kv_dtype`` there); MLA latent pages stay fp because rank is
a *contracted* dim — per-page latent scales would reassociate the absorbed
sums, so ``kv_dtype="int8"`` + ``attn_kind="mla"`` is rejected.

Import discipline: this module depends only on jax — it sits *below* both
``repro.models.registry`` (which imports ``layout_for``) and
``repro.serving.paged`` (which takes a layout), so neither layer reaches
around the seam.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

P = jax.sharding.PartitionSpec

#: storage dtypes a layout's data leaves may use ("fp32" = native compute
#: dtype — the name records what the *bench baselines* store, fp32 on the
#: smoke configs).  ``ServeConfig.kv_dtype`` validates against this.
KV_DTYPES = ("fp32", "int8")

#: suffix of the per-row scale leaf a quantized layout carries beside each
#: data leaf ("k" -> "k_scale")
SCALE_SUFFIX = "_scale"


def quantize_kv(x):
    """Symmetric per-row int8 quantization of a K/V leaf: one bfloat16
    scale per (..., head) row over the trailing head_dim.  Returns
    ``(q, scale)`` with ``q`` int8 in [-127, 127] and
    ``x ≈ q * scale[..., None]``.

    The scale is *stored* bf16 (half the overhead of fp32 — what keeps the
    quantized page under the 0.30x budget on the hd=16 smoke shapes) but
    the row is divided by the bf16-*rounded* value, so dequant with the
    stored scale reconstructs exactly what was quantized; the clip guards
    the ≤0.4% bf16 round-down that could push a ratio past 127.

    This is the ONLY quantizer — the decode append, the prefill scatter and
    the whole-state insert path all call it, so a written token's page
    bytes are a pure function of its fp row (the warm/cold, mesh and
    kernel-on/off identity argument for quantized layouts)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.bfloat16)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / sf[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def check_kv_dtype_layout(kv_dtype: str, layout: Optional["KVLayout"]) -> None:
    """Quantized KV needs a per-head paged layout.  The ONLY implementation
    of this rule — ``quantized_layout`` (engine-side derivation) and
    ``ServeConfig.check_kv_dtype`` (engine-level validation) both call it.

    MLA latent pages stay fp: the latent rank is a *contracted* dim of the
    absorbed-decode einsums, so per-page scales would reassociate those
    sums and break the latent == per-head equivalence."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype={kv_dtype!r} not in {KV_DTYPES}")
    if kv_dtype == "fp32":
        return
    if layout is None:
        raise ValueError(
            f"kv_dtype={kv_dtype!r} requires a paged KV layout, but this "
            "family serves slotted-only (no layout) — drop kv_dtype or "
            "pick a paged family")
    if layout.name == "latent":
        raise ValueError(
            f"kv_dtype={kv_dtype!r} cannot quantize MLA latent pages "
            f"(attn_kind='mla'): the latent rank is a contracted dim, so "
            "per-page scales would reassociate the absorbed sums — use "
            "kv_dtype='fp32' with attn_kind='mla'")


def check_window_page_size(page_size: int, window: int) -> None:
    """Ring-wrapped window pages must *tile* the window: a page larger than
    the window could never fill before rotating out (so it could never be
    cached or freed correctly), and a page that doesn't divide the window
    would straddle the wrap point.  The ONLY implementation of this rule —
    ``KVLayout.check_page_size`` (pool construction) and
    ``ServeConfig.check_window`` (engine-level validation) both call it."""
    if window <= 0:
        return
    if page_size > window:
        raise ValueError(
            f"page_size={page_size} exceeds the attention window="
            f"{window}: a page that never fits the window can never be "
            "cached or freed correctly — shrink page_size or force "
            "kv_layout='slotted'")
    if window % page_size:
        raise ValueError(
            f"page_size={page_size} does not divide the attention "
            f"window={window}: ring-wrapped window pages must tile the "
            "window exactly")


@dataclass(frozen=True)
class KVLayout:
    """Physical page layout of one attention family's decode cache."""
    name: str                    # "kv" | "latent" | "window"
    leaves: Tuple[str, ...]      # decode-state leaves the pool pages
    window: int = 0              # > 0: ring-wrapped window pages
    kv_dtype: str = "fp32"       # "fp32" (native) | "int8" (+ scale leaves)

    # -- geometry ----------------------------------------------------------

    @property
    def ring(self) -> bool:
        return self.window > 0

    @property
    def quantized(self) -> bool:
        return self.kv_dtype != "fp32"

    @property
    def data_leaves(self) -> Tuple[str, ...]:
        """The K/V-carrying leaves — ``leaves`` minus the scale leaves a
        quantized layout appends.  These are the names present in the
        bundle's native decode state (``init_decode_state`` / slotted
        prefill caches); scale leaves exist only in the page pool."""
        return tuple(n for n in self.leaves if not n.endswith(SCALE_SUFFIX))

    def page_template(self, blank: dict) -> dict:
        """One-page pool template from the bundle's native blank state:
        identity for fp layouts; quantized layouts store each data leaf as
        int8 and add a per-row bf16 scale leaf (the data leaf's shape with
        head_dim dropped — one scale per (page, offset, kv-head))."""
        if not self.quantized:
            return {k: blank[k] for k in self.leaves}
        one = {}
        for name in self.data_leaves:
            x = blank[name]
            one[name] = jnp.zeros(x.shape, jnp.int8)
            one[name + SCALE_SUFFIX] = jnp.zeros(x.shape[:-1], jnp.bfloat16)
        return one

    def check_page_size(self, page_size: int) -> None:
        """Ring layouts need pages that tile the window (see
        ``check_window_page_size`` — the single home of that rule, also
        reached through ``ServeConfig.check_window``)."""
        check_window_page_size(page_size, self.window)

    def max_page_size(self) -> int:
        """Largest power-of-two page that satisfies ``check_page_size``:
        the lowest set bit of the window (every smaller power of two also
        tiles it).  Unbounded (2**62) for contiguous layouts — callers
        min() it against their own caps."""
        return self.window & -self.window if self.window else 1 << 62

    def table_width(self, pages_per_slot: int, page_size: int) -> int:
        """Page-table columns per slot: the full logical block count for
        contiguous layouts, the ring size for windowed ones."""
        if not self.window:
            return pages_per_slot
        return min(pages_per_slot, self.window // page_size)

    def cell(self, block: int, width: int) -> int:
        """Table column holding logical block ``block``."""
        return block % width if self.ring else block

    def live_tokens(self, seq_len: int) -> int:
        """Tokens of K/V a slot holds at sequence length ``seq_len`` — what
        a slot-granular pool would preallocate (the telemetry comparator)."""
        return min(seq_len, self.window) if self.ring else seq_len

    def max_chunk_tokens(self, padded_len: int) -> int:
        """Largest prefill chunk the layout can absorb in one write-then-
        attend step.  A ring chunk longer than the window would overwrite
        cells its own early queries (and the snapshot gather) still need."""
        return self.window if self.ring else padded_len

    def max_decode_span(self, n_steps: int) -> int:
        """Longest decode span one fused multi-step dispatch may write.
        The pipelined engine prepares a slot's whole span (positions
        ``pos..pos+span-1``) before dispatching its ``lax.scan`` decode;
        a ring span longer than the window would wrap onto cells whose
        keys its own earlier scan iterations still attend — same hazard,
        same bound as ``max_chunk_tokens``.  Contiguous layouts are
        unconstrained."""
        return min(n_steps, self.window) if self.ring else n_steps

    def needed_start(self, cached_tokens: int, page_size: int) -> int:
        """First prompt block a new admission must still be able to *read*
        when ``cached_tokens`` are served from the prefix cache: suffix
        queries start at position ``cached_tokens`` and attend keys no
        older than ``cached_tokens - window + 1`` — earlier blocks are
        wholly masked and need no live page (contiguous layouts need every
        block)."""
        if not self.window:
            return 0
        return max(0, cached_tokens - self.window + 1) // page_size

    # -- observability -----------------------------------------------------

    def describe(self) -> dict:
        """Flat JSON-friendly identity for trace metadata: which physical
        page format a trace was captured against (``repro.obs`` stamps it
        into the Chrome trace's ``otherData`` and the pool's init event),
        so an attribution number is never read against the wrong layout."""
        return {"layout": self.name, "leaves": list(self.leaves),
                "window": self.window, "ring": self.ring,
                "kv_dtype": self.kv_dtype}

    # -- sharding ----------------------------------------------------------

    def page_pspec(self, name: str, leaf, model_size: int):
        """PartitionSpec for one page-pool leaf.  KV-head (or head_dim) of
        per-head pages shards over "model" when divisible (a *batch* dim of
        the attention einsums — sharding it never reassociates a sum).
        Latent (ckv/krope) pages replicate: the rank is a *contracted* dim
        in every absorbed-MLA einsum, so sharding it would split dot
        products across devices and break bitwise equivalence with the
        single-device decode — and the latent cache is small by
        construction (that is MLA's point), so replication is cheap.
        Pages themselves always replicate over data axes — any slot's
        pages live anywhere."""
        spec = [None] * leaf.ndim
        if model_size > 1 and name in ("k", "v") and leaf.ndim == 5:
            if leaf.shape[3] % model_size == 0:           # [L,P,ps,KV,hd]
                spec[3] = "model"
            elif leaf.shape[4] % model_size == 0:
                spec[4] = "model"
        if model_size > 1 and name in ("k_scale", "v_scale") \
                and leaf.ndim == 4:
            # [L,P,ps,KV] — shard KV exactly when the int8 data leaf does
            # (same divisibility test on the same axis); when the data leaf
            # fell back to head_dim sharding the scales replicate, which is
            # consistent because their KV dim is then unsharded too.
            if leaf.shape[3] % model_size == 0:
                spec[3] = "model"
        return P(*spec)


#: the three shipped layouts (module-level so capability checks and tests
#: can name them without constructing)
KV_FULL = KVLayout("kv", ("k", "v"))
KV_LATENT = KVLayout("latent", ("ckv", "krope"))


def quantized_layout(base: Optional[KVLayout],
                     kv_dtype: str) -> Optional[KVLayout]:
    """Derive the ``kv_dtype`` storage variant of a base layout: identity
    for "fp32"; "int8" appends one ``*_scale`` leaf per data leaf and marks
    the layout quantized.  The engine applies ``ServeConfig.kv_dtype`` here
    (right beside its ``check_window`` call); raises the same ValueError as
    ``check_kv_dtype_layout`` for un-quantizable layouts (MLA latent /
    slotted-only)."""
    if kv_dtype == "fp32":
        return base
    check_kv_dtype_layout(kv_dtype, base)
    leaves = base.leaves + tuple(n + SCALE_SUFFIX for n in base.leaves)
    return KVLayout(base.name, leaves, window=base.window, kv_dtype=kv_dtype)


def layout_for(cfg, kv_dtype: str = "fp32") -> Optional[KVLayout]:
    """The capability authority: which page layout (if any) serves this
    model config's decode cache.  Returns None for families whose state has
    nothing to page (recurrent O(1) state) — they stay on the slotted pool.

    Callers pass a transformer-family ``ModelConfig``; the registry only
    consults this for families whose decode cache *is* the transformer
    cache (dense / moe), so recurrent hybrids with attention sub-blocks
    never reach here.  ``kv_dtype`` (the ``ServeConfig`` knob) selects the
    storage variant: "int8" emits layouts whose data leaves are int8 pages
    with per-row scale leaves — rejected for MLA with an error naming both
    knobs (latent rank is contracted; see ``check_kv_dtype_layout``).
    """
    kind = getattr(cfg, "attn_kind", "none")
    if kind == "full":
        base = KV_FULL
    elif kind == "mla":
        base = KV_LATENT
    elif kind in ("swa", "local") and getattr(cfg, "window", 0) > 0:
        base = KVLayout("window", ("k", "v"), window=cfg.window)
    else:
        return None
    return quantized_layout(base, kv_dtype)
