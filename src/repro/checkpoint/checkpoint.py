"""Sharded checkpoint / restore with async save.

The paper defers fault tolerance to ULFM ("continued execution in the
presence of faults", §II-B) and notes that data parallelism replicates the
critical state for free.  We implement the mechanism that makes that real
on a JAX cluster:

  * atomic on-disk checkpoints (tmp dir + rename), one .npy per leaf +
    a JSON manifest with the treedef, step and mesh fingerprint;
  * async save: device->host transfer on the caller thread (cheap),
    file I/O on a background thread — training continues;
  * restore onto ANY target mesh/sharding (elastic.py uses this to resume
    on a shrunk/grown data axis — replicated DP state makes this trivial,
    exactly the paper's §III-B argument).

Layout:  <dir>/step_000123/
             manifest.json
             leaf_00000.npy ...
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> List[str]:
    return [jax.tree_util.keystr(kp)
            for kp, _ in jax.tree_util.tree_leaves_with_path(tree)]


def save_checkpoint(directory, state, step: int, *, blocking: bool = True,
                    keep: int = 3) -> "SaveHandle":
    """Checkpoint a pytree of jax/np arrays.  Returns a SaveHandle; call
    ``.wait()`` (or save with blocking=True) before relying on durability."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    # device->host on the caller thread (arrays may be donated right after)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    manifest = {
        "step": int(step),
        "n_leaves": len(host_leaves),
        "treedef": str(treedef),
        "paths": _leaf_paths(state),
        "shapes": [list(x.shape) for x in host_leaves],
        "dtypes": [str(x.dtype) for x in host_leaves],
        "time": time.time(),
    }
    handle = SaveHandle(directory, step)

    def _write():
        tmp = directory / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, arr in enumerate(host_leaves):
            np.save(tmp / f"leaf_{i:05d}.npy", arr)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = directory / f"step_{step:09d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _prune(directory, keep)
        handle._done.set()

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
    return handle


class SaveHandle:
    def __init__(self, directory: Path, step: int):
        self.directory = directory
        self.step = step
        self._done = threading.Event()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)


def _prune(directory: Path, keep: int):
    steps = sorted(directory.glob("step_*"))
    for old in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    steps = sorted(directory.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(directory, like, *, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of shardings
    for direct sharded placement on the current mesh."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), \
        (manifest["n_leaves"], len(leaves_like))
    host = [np.load(d / f"leaf_{i:05d}.npy")
            for i in range(manifest["n_leaves"])]
    for arr, ref in zip(host, leaves_like):
        assert tuple(arr.shape) == tuple(ref.shape), (arr.shape, ref.shape)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
        out = [jax.device_put(a, s) for a, s in zip(host, sh_leaves)]
    else:
        out = [jax.numpy.asarray(a) for a in host]
    return jax.tree_util.tree_unflatten(treedef, out), step
