"""Failure handling: straggler detection + supervised recovery loop.

Two mechanisms the paper's large-scale story needs (§II-B discusses ULFM as
the path to MPI fault tolerance; we provide the runtime policy layer):

  * ``StragglerMonitor`` — robust step-time outlier detection (median +
    k·MAD).  On a real pod this feeds the decision to evict/replace a slow
    host; here it also powers tests and the benchmark harness.

  * ``run_with_recovery`` — the supervision loop: run steps, checkpoint
    every N, on failure rebuild (possibly smaller — elastic.py) and resume
    from the last durable checkpoint.  ``FaultInjector`` simulates host
    loss deterministically for tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np


class SimulatedFault(RuntimeError):
    pass


@dataclass
class FaultInjector:
    """Raises SimulatedFault at the given global steps (once each)."""
    fail_at_steps: Tuple[int, ...] = ()
    _fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


class StragglerMonitor:
    """Flags steps (or ranks) whose duration exceeds median + k*MAD."""

    def __init__(self, k: float = 5.0, window: int = 50, warmup: int = 3):
        self.k = k
        self.window = window
        self.warmup = warmup
        self.times: List[float] = []
        self.flagged: List[int] = []

    def record(self, duration_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(duration_s)
        hist = self.times[-self.window:]
        if len(self.times) <= self.warmup or len(hist) < 5:
            return False
        med = float(np.median(hist[:-1]))
        mad = float(np.median(np.abs(np.asarray(hist[:-1]) - med))) or 1e-9
        is_straggler = duration_s > med + self.k * mad
        if is_straggler:
            self.flagged.append(len(self.times) - 1)
        return is_straggler

    def summary(self):
        arr = np.asarray(self.times) if self.times else np.zeros(1)
        return {"steps": len(self.times), "mean_s": float(arr.mean()),
                "p50_s": float(np.median(arr)),
                "p95_s": float(np.percentile(arr, 95)),
                "stragglers": list(self.flagged)}


def run_with_recovery(*, make_trainer: Callable[[int], object],
                      data_iter_factory: Callable[[int], object],
                      ckpt_dir, total_steps: int, ckpt_every: int = 10,
                      injector: Optional[FaultInjector] = None,
                      max_restarts: int = 3, lost_replicas_per_failure: int = 0,
                      async_ckpt: bool = False):
    """Supervised training with checkpoint/restart (+ optional elastic shrink).

    make_trainer(attempt) -> TransparentTrainer (attempt>0 may build a
    smaller mesh); data_iter_factory(start_step) -> iterator of batches.
    Returns (final_state, history dict).
    """
    from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                             save_checkpoint)
    history = {"losses": [], "restarts": 0, "resume_steps": []}
    attempt = 0
    monitor = StragglerMonitor()

    while attempt <= max_restarts:
        trainer = make_trainer(attempt)
        start = latest_step(ckpt_dir)
        if start is None:
            state = trainer.init(0)
            start = 0
        else:
            from repro.checkpoint.elastic import restore_elastic
            state, start = restore_elastic(ckpt_dir, trainer)
            history["resume_steps"].append(start)
        it = iter(data_iter_factory(start))
        step = start
        try:
            while step < total_steps:
                batch = next(it)
                if injector is not None:
                    injector.check(step)
                t0 = time.time()
                state, metrics = trainer.step(state, batch)
                monitor.record(time.time() - t0)
                step = int(metrics["step"])
                history["losses"].append((step, float(metrics["loss"])))
                if step % ckpt_every == 0 or step == total_steps:
                    save_checkpoint(ckpt_dir, state, step,
                                    blocking=not async_ckpt)
            history["straggler_summary"] = monitor.summary()
            return state, history
        except SimulatedFault:
            history["restarts"] += 1
            attempt += 1
    raise RuntimeError(f"exceeded {max_restarts} restarts")
