"""Elastic re-meshing: resume a run on a different device count.

The paper's §III-B argument — "by using data parallelism the critical data
structures are automatically replicated for fault tolerance" — becomes an
executable mechanism here: because DP state is replicated (or flat-sharded
with a canonical global layout), a checkpoint taken on an N-replica mesh
restores onto an M-replica mesh by re-placing the same logical arrays under
the new NamedShardings.  Combined with checkpoint.py this gives
ULFM-style *continued execution*: lose a host -> rebuild a smaller mesh ->
restore -> keep training (see failures.py for the supervision loop).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np

from repro.configs.base import MeshConfig, RunConfig
from repro.checkpoint.checkpoint import restore_checkpoint, latest_step


def shrink_mesh_config(mesh_cfg: MeshConfig, lost_replicas: int = 1) -> MeshConfig:
    """Drop data-parallel replicas (the failure-absorbing axis)."""
    shape = list(mesh_cfg.shape)
    for i, a in enumerate(mesh_cfg.axis_names):
        if a == "data":
            new = shape[i] - lost_replicas
            if new < 1:
                raise ValueError("cannot shrink below one data replica")
            shape[i] = new
    return dataclasses.replace(mesh_cfg, shape=tuple(shape))


def rebatch_for_mesh(global_batch: int, old_dp: int, new_dp: int,
                     keep_global: bool = True) -> int:
    """Elastic batch policy: keep the global batch (per-replica grows) or
    keep per-replica batch (global shrinks — changes optimization slightly,
    which the supervisor must log)."""
    if keep_global:
        assert global_batch % new_dp == 0, (global_batch, new_dp)
        return global_batch
    per = global_batch // old_dp
    return per * new_dp


def restore_elastic(ckpt_dir, trainer, *, step: Optional[int] = None):
    """Restore a checkpoint onto ``trainer``'s (possibly different) mesh.

    Works for replicated and fsdp modes directly; for the zero1 flat-shard
    optimizer the loader re-pads/re-splits the canonical flat vectors when
    the DP degree changed.
    """
    like = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        trainer.state_structs())
    shardings = trainer.state_shardings()

    # detect zero1 flat-state shape mismatch (dp changed)
    import json
    from pathlib import Path
    directory = Path(ckpt_dir)
    s = step if step is not None else latest_step(directory)
    manifest = json.loads(
        (directory / f"step_{s:09d}" / "manifest.json").read_text())
    like_shapes = [tuple(l.shape) for l in jax.tree_util.tree_leaves(like)]
    saved_shapes = [tuple(x) for x in manifest["shapes"]]
    if like_shapes == saved_shapes:
        return restore_checkpoint(ckpt_dir, like, step=step,
                                  shardings=shardings)

    # re-split path: load raw, reconcile flat [dp, shard] leaves
    raw, s = restore_checkpoint(ckpt_dir, None, step=step) \
        if False else _load_raw(directory, s)
    new_leaves = []
    for arr, ref in zip(raw, jax.tree_util.tree_leaves(like)):
        if tuple(arr.shape) == tuple(ref.shape):
            new_leaves.append(arr)
            continue
        if arr.ndim == 2 and ref.ndim == 2 and arr.shape[0] != ref.shape[0]:
            flat = arr.reshape(-1)
            want = ref.shape[0] * ref.shape[1]
            flat = np.pad(flat, (0, max(0, want - flat.size)))[:want]
            new_leaves.append(flat.reshape(ref.shape))
            continue
        raise ValueError(f"cannot reconcile leaf {arr.shape} -> {ref.shape}")
    treedef = jax.tree_util.tree_structure(like)
    state = jax.tree_util.tree_unflatten(treedef, new_leaves)
    sh_leaves = jax.tree_util.tree_leaves(shardings)
    placed = [jax.device_put(a, sh) for a, sh in
              zip(jax.tree_util.tree_leaves(state), sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, placed), s


def _load_raw(directory, step: int):
    import json
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    return [np.load(d / f"leaf_{i:05d}.npy")
            for i in range(manifest["n_leaves"])], step
