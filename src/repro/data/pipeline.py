"""Input pipeline: rank-sharded iteration + background prefetch + global
device batches.

On a multi-host TPU deployment each jax process feeds only its addressable
shard (``jax.make_array_from_process_local_data``); on the single-process
CPU container the same code path degenerates to a full-batch put with the
correct NamedSharding.  The trainer consumes global arrays either way — the
pipeline is the MaTEx data-reader abstraction (§III-F) end to end.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.data.readers import DataSet

P = jax.sharding.PartitionSpec


class BatchIterator:
    """Deterministic epoch shuffling + drop-last batching over a DataSet."""

    def __init__(self, ds: DataSet, batch: int, seed: int = 0,
                 shuffle: bool = True, label_key: str = "labels",
                 data_key: str = "tokens"):
        self.ds = ds
        self.batch = batch
        self.seed = seed
        self.shuffle = shuffle
        self.data_key = data_key
        self.label_key = label_key
        self.epoch = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        n = len(self.ds.training_data)
        while True:
            idx = np.arange(n)
            if self.shuffle:
                rng = np.random.default_rng(self.seed + self.epoch)
                rng.shuffle(idx)
            for i in range(0, n - self.batch + 1, self.batch):
                sel = idx[i:i + self.batch]
                yield {self.data_key: self.ds.training_data[sel],
                       self.label_key: self.ds.training_labels[sel]}
            self.epoch += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded), hiding host read latency
    behind device compute — the I/O consideration of paper §III-F."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._fill, args=(iter(it),),
                                       daemon=True)
        self.thread.start()

    def _fill(self, it):
        try:
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def device_put_global(batch: Dict[str, np.ndarray], mesh,
                      dp_axes: Tuple[str, ...]):
    """Host batch -> global jax arrays sharded batch-dim over the DP axes."""
    def one(x):
        spec = P(tuple(dp_axes), *([None] * (x.ndim - 1)))
        sharding = jax.sharding.NamedSharding(mesh, spec)
        if jax.process_count() > 1:
            return jax.make_array_from_process_local_data(sharding, x)
        return jax.device_put(x, sharding)
    return jax.tree.map(one, batch)


def make_input_pipeline(ds: DataSet, global_batch: int, mesh,
                        dp_axes: Tuple[str, ...], *, seed: int = 0,
                        prefetch: int = 2, data_key: str = "tokens",
                        label_key: str = "labels"):
    """Full pipeline: shard -> shuffle -> batch -> prefetch -> device arrays."""
    world = max(jax.process_count(), 1)
    local_batch = global_batch // world
    it = BatchIterator(ds, local_batch, seed=seed, data_key=data_key,
                       label_key=label_key)
    pf = Prefetcher(iter(it), depth=prefetch)

    def gen():
        for host_batch in pf:
            yield device_put_global(host_batch, mesh, dp_axes)

    return gen(), pf
