"""Parallel data readers — the paper's §III-F.

MaTEx's data readers are the ONE thing a user changes in their script
(Fig. 3): they read a dataset and transparently hand each rank its shard.
Formats mirror the paper's list — CSV, MNIST/CIFAR binary, NumPy (the
paper's parallel NetCDF is replaced by .npy memmap: no netCDF lib offline)
— plus a synthetic token stream for LM work.

Sharding semantics: deterministic strided partition by (rank, world):
sample i belongs to rank ``i % world``.  Every reader yields *local* batches
of ``global_batch // world``; the pipeline (pipeline.py) assembles global
jax arrays with the right device sharding.
"""
from __future__ import annotations

import csv
import gzip
import struct
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class DataSet:
    """Paper-style container: training/validation arrays, rank-sharded."""
    training_data: np.ndarray
    training_labels: np.ndarray
    validation_data: Optional[np.ndarray] = None
    validation_labels: Optional[np.ndarray] = None


def _shard(arr: np.ndarray, rank: int, world: int) -> np.ndarray:
    return arr[rank::world]


# ---------------------------------------------------------------------------
# Synthetic LM tokens
# ---------------------------------------------------------------------------

def synthetic_tokens(vocab: int, seq_len: int, num_samples: int,
                     rank: int = 0, world: int = 1, seed: int = 0) -> DataSet:
    """Deterministic synthetic corpus: every rank derives its shard from the
    same global stream (so DP runs are reproducible and shards disjoint)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, (num_samples, seq_len + 1), dtype=np.int32)
    toks = _shard(toks, rank, world)
    return DataSet(training_data=toks[:, :-1], training_labels=toks[:, 1:])


# ---------------------------------------------------------------------------
# NumPy (.npy / .npz) — the NetCDF stand-in
# ---------------------------------------------------------------------------

def numpy_reader(data_path: str, labels_path: Optional[str] = None,
                 rank: int = 0, world: int = 1, mmap: bool = True) -> DataSet:
    mode = "r" if mmap else None
    data = np.load(data_path, mmap_mode=mode)
    labels = np.load(labels_path, mmap_mode=mode) if labels_path else \
        np.zeros(len(data), np.int32)
    return DataSet(training_data=np.asarray(_shard(data, rank, world)),
                   training_labels=np.asarray(_shard(labels, rank, world)))


# ---------------------------------------------------------------------------
# CSV (last column = label, like MaTEx's csv reader)
# ---------------------------------------------------------------------------

def csv_reader(path: str, rank: int = 0, world: int = 1,
               has_header: bool = False, label_col: int = -1) -> DataSet:
    rows = []
    with open(path, newline="") as f:
        r = csv.reader(f)
        if has_header:
            next(r, None)
        for row in r:
            if row:
                rows.append([float(x) for x in row])
    arr = np.asarray(rows, np.float32)
    labels = arr[:, label_col].astype(np.int32)
    data = np.delete(arr, label_col % arr.shape[1], axis=1)
    return DataSet(training_data=_shard(data, rank, world),
                   training_labels=_shard(labels, rank, world))


# ---------------------------------------------------------------------------
# MNIST / CIFAR binary formats (paper-native)
# ---------------------------------------------------------------------------

def mnist_reader(images_path: str, labels_path: str,
                 rank: int = 0, world: int = 1) -> DataSet:
    """idx-ubyte format (gzip or raw)."""
    def _open(p):
        return gzip.open(p, "rb") if str(p).endswith(".gz") else open(p, "rb")

    with _open(images_path) as f:
        magic, n, rows, cols = struct.unpack(">IIII", f.read(16))
        assert magic == 2051, f"bad MNIST image magic {magic}"
        imgs = np.frombuffer(f.read(n * rows * cols), np.uint8)
        imgs = imgs.reshape(n, rows, cols, 1).astype(np.float32) / 255.0
    with _open(labels_path) as f:
        magic, n2 = struct.unpack(">II", f.read(8))
        assert magic == 2049, f"bad MNIST label magic {magic}"
        labels = np.frombuffer(f.read(n2), np.uint8).astype(np.int32)
    return DataSet(training_data=_shard(imgs, rank, world),
                   training_labels=_shard(labels, rank, world))


def cifar_reader(path: str, rank: int = 0, world: int = 1,
                 coarse: bool = False) -> DataSet:
    """CIFAR-10 binary: rows of [label, 3072 bytes RGB]."""
    raw = np.fromfile(path, np.uint8)
    row = 3073
    n = len(raw) // row
    raw = raw[:n * row].reshape(n, row)
    labels = raw[:, 0].astype(np.int32)
    imgs = raw[:, 1:].reshape(n, 3, 32, 32).transpose(0, 2, 3, 1)
    imgs = imgs.astype(np.float32) / 255.0
    return DataSet(training_data=_shard(imgs, rank, world),
                   training_labels=_shard(labels, rank, world))


READERS = {
    "synthetic": synthetic_tokens,
    "numpy": numpy_reader,
    "csv": csv_reader,
    "mnist": mnist_reader,
    "cifar": cifar_reader,
}
