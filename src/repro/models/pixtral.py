"""Pixtral-style VLM backbone: mistral-nemo decoder + stub vision frontend.

Per the assignment, ``[vlm]`` entries exercise the transformer backbone only:
``input_specs()`` provides precomputed patch embeddings [B, n_img, D]
(the pixtral-ViT tower is a stub).  Patch embeddings are projected through a
learned multimodal adapter and *prepended* to the token embeddings; training
labels over image positions are masked (-100 idiom).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, apply_norm, cast_tree, dot
from repro.models.transformer import (cross_entropy, decode_cache_specs,
                                      decoder_layer_apply, embed_lookup,
                                      init_decode_caches, lm_head, lm_specs,
                                      lm_forward)


def pixtral_specs(cfg):
    d = cfg.d_model
    specs = lm_specs(cfg)
    specs["adapter"] = {
        "w_in": ParamSpec((d, d), ("embed", "embed2")),
        "b_in": ParamSpec((d,), ("embed2",), init="zeros"),
    }
    return specs


def _prepend_patches(cfg, params, tokens, patches, cd):
    """Embed tokens, adapter-project patches, concatenate [img ; text]."""
    tok_emb = embed_lookup(cfg, params, tokens, cd)
    img = dot(patches.astype(cd), params["adapter"]["w_in"], cd)
    img = img + params["adapter"]["b_in"].astype(cd)
    return jnp.concatenate([img, tok_emb], axis=1)


def pixtral_loss(cfg, params, batch):
    """batch: {"tokens": [B,S_text], "patches": [B,n_img,D], "labels": [B,S_text]}"""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    x = _prepend_patches(cfg, params, batch["tokens"], batch["patches"], cd)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    import functools
    from repro.models.transformer import _remat
    layer_fn = _remat(cfg, functools.partial(decoder_layer_apply, cfg))

    def body(carry, lp):
        x, aux = carry
        x, _, a = layer_fn(lp, x, positions)
        return (x, aux + a), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    n_img = batch["patches"].shape[1]
    logits = lm_head(cfg, params, x[:, n_img:])       # predict text positions only
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


def pixtral_prefill(cfg, params, tokens, patches):
    """Prefill over [img ; text]; returns (last_logits, caches)."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    x0 = _prepend_patches(cfg, params, tokens, patches, cd)
    S = x0.shape[1]
    # reuse lm_forward's cache-collecting scan by substituting the embedding:
    # emulate via a token path is not possible (inputs are embeddings), so we
    # inline the same scan here.
    import functools
    from repro.models import attention as attn
    from repro.models import mlp as mlp_mod
    from repro.models import moe as moe_mod
    from repro.models.transformer import _fill_kv_cache
    B = x0.shape[0]
    positions = jnp.arange(S, dtype=jnp.int32)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def body(x, lp):
        h = apply_norm(cfg, lp["ln1"], x)
        a, _ = attn.attention_apply(cfg, lp["attn"], h, positions)
        k = dot(h, lp["attn"]["wk"], cd).reshape(B, S, kv, hd)
        v = dot(h, lp["attn"]["wv"], cd).reshape(B, S, kv, hd)
        k = attn.apply_rope(k, positions, cfg.rope_theta)
        cache = _fill_kv_cache(k, v, positions, S)
        x = x + a
        h2 = apply_norm(cfg, lp["ln2"], x)
        x = x + mlp_mod.mlp_apply(cfg, lp["ff"], h2)
        return x, cache

    x, caches = jax.lax.scan(body, x0, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x[:, -1:])
    return logits[:, 0], caches
