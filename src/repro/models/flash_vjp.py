"""Flash-attention forward/backward with custom VJP — O(S) residuals.

Differentiating the scanned attention (attention_core) stacks the score
blocks as scan residuals even under jax.checkpoint (the scan transpose
needs them; EXPERIMENTS.md §Perf cell-1 iter 7 measures the refutation).
This module implements the standard FlashAttention backward: save only
(O, L=logsumexp) per row, recompute P block-by-block in the backward and
accumulate dq / dk / dv in scan carries — no stacked probability tensors.

Layout [B, S, KV, G, hd] internally; public API matches attention_core for
the causal/windowed self-attention case (q_pos == k_pos == arange).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _blocks(x, nb, bs, axis=1):
    # [B, S, ...] -> [nb, B, bs, ...]
    shape = x.shape
    x = x.reshape(shape[0], nb, bs, *shape[2:])
    return jnp.moveaxis(x, 1, 0)


def _unblocks(x, S):
    # [nb, B, bs, ...] -> [B, S, ...]
    x = jnp.moveaxis(x, 0, 1)
    return x.reshape(x.shape[0], S, *x.shape[3:])


def _mask(q0, k0, bq, bk, S, causal, window):
    qp = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = kp < S
    if causal:
        m &= kp <= qp
    if window:
        m &= kp > qp - window
    return m


def _fwd(q, k, v, causal, window, bq, bk):
    """Returns (out fp32 [B,S,H? -> B,S,KV,G,hd], L [B,S,KV,G])."""
    B, S, KV, G, hd = q.shape
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5
    qb = _blocks(q, nq, bq)                         # [nq,B,bq,KV,G,hd]
    kb = _blocks(k, nk, bk)                         # [nk,B,bk,KV,hd]
    vb = _blocks(v, nk, bk)

    def q_step(_, qi):
        qblk, iq = qi

        def kv_step(carry, kj):
            m_p, l_p, acc = carry
            kblk, vblk, ik = kj
            s = jnp.einsum("bqkgh,btkh->bkgqt", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(iq * bq, ik * bk, bq, bk, S, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_n = jnp.maximum(m_p, jnp.max(s, -1))
            corr = jnp.exp(m_p - m_n)
            p = jnp.exp(s - m_n[..., None])
            l_n = l_p * corr + jnp.sum(p, -1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_n, l_n, acc), None

        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        L = m + jnp.log(jnp.maximum(l, 1e-30))            # logsumexp rows
        return None, (o.transpose(0, 3, 1, 2, 4),          # [B,bq,KV,G,hd]
                      L.transpose(0, 3, 1, 2))             # [B,bq,KV,G]

    _, (ob, Lb) = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    return _unblocks(ob, S), _unblocks(Lb, S)


def _bwd(res, do, causal, window, bq, bk):
    q, k, v, o, L = res
    B, S, KV, G, hd = q.shape
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5
    do = do.astype(jnp.float32)
    # D_i = rowsum(dO * O)
    D = jnp.sum(do * o, axis=-1)                          # [B,S,KV,G]
    qb = _blocks(q, nq, bq)
    dob = _blocks(do, nq, bq)
    Lb = _blocks(L, nq, bq)
    Db = _blocks(D, nq, bq)
    kb = _blocks(k, nk, bk)
    vb = _blocks(v, nk, bk)

    def kv_step(dq_full, kj):
        """Outer scan over kv blocks; carry = dq accumulator [nq,...]."""
        kblk, vblk, ik = kj

        def q_step(carry, qi):
            dkj, dvj = carry
            qblk, doblk, Lblk, Dblk, iq = qi
            s = jnp.einsum("bqkgh,btkh->bkgqt", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(iq * bq, ik * bk, bq, bk, S, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            p = jnp.exp(s - Lblk.transpose(0, 2, 3, 1)[..., None])  # [B,KV,G,bq,bk]
            dov = jnp.einsum("bqkgh,btkh->bkgqt", doblk, vblk,
                             preferred_element_type=jnp.float32)
            ds = p * (dov - Dblk.transpose(0, 2, 3, 1)[..., None]) * scale
            dq_blk = jnp.einsum("bkgqt,btkh->bqkgh", ds, kblk,
                                preferred_element_type=jnp.float32)
            dkj = dkj + jnp.einsum("bkgqt,bqkgh->btkh", ds, qblk,
                                   preferred_element_type=jnp.float32)
            dvj = dvj + jnp.einsum("bkgqt,bqkgh->btkh",
                                   p.astype(jnp.float32), doblk,
                                   preferred_element_type=jnp.float32)
            return (dkj, dvj), dq_blk

        z = jnp.zeros((B, bk, KV, hd), jnp.float32)
        (dkj, dvj), dq_blocks = jax.lax.scan(
            q_step, (z, z), (qb, dob, Lb, Db, jnp.arange(nq)))
        return dq_full + dq_blocks, (dkj, dvj)

    dq0 = jnp.zeros((nq, B, bq, KV, G, hd), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_step, dq0, (kb, vb, jnp.arange(nk)))
    return (_unblocks(dq, S).astype(q.dtype),
            _unblocks(dk, S).astype(k.dtype),
            _unblocks(dv, S).astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, bq, bk):
    out, _ = _fwd(q, k, v, causal, window, bq, bk)
    return out.astype(q.dtype)


def _flash_fwd(q, k, v, causal, window, bq, bk):
    out, L = _fwd(q, k, v, causal, window, bq, bk)
    return out.astype(q.dtype), (q, k, v, out, L)


def _flash_bwd(causal, window, bq, bk, res, g):
    return _bwd(res, g, causal, window, bq, bk)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_vjp(q, k, v, *, causal: bool = True, window: int = 0,
                        q_block: int = 512, kv_block: int = 1024):
    """Drop-in for attention_core on self-attention (contiguous positions).

    q: [B,S,H,hd]; k,v: [B,S,KV,hd] -> [B,S,H,hd].  Pads S to tile size.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(q_block, S)
    bk = min(kv_block, S)
    S_p = -(-S // max(bq, bk)) * max(bq, bk)
    if S_p % bq:
        S_p = -(-S_p // bq) * bq
    pad = S_p - S
    q5 = q.reshape(B, S, KV, G, hd)
    if pad:
        q5 = jnp.pad(q5, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = _flash(q5, k, v, causal, window, bq, bk)
    return out[:, :S].reshape(B, S, H, hd)
