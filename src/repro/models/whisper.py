"""Whisper-style encoder-decoder backbone (audio frontend is a STUB).

Per the assignment, ``[audio]`` entries exercise the transformer backbone
only: ``input_specs()`` provides precomputed frame embeddings [B, T_enc, D]
in place of the mel-spectrogram conv frontend.

Whisper idioms kept: pre-LN layernorm, GELU MLP with biases, learned
positions, cross-attention in every decoder layer, sinusoid-free stub.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (ParamSpec, apply_norm, cast_tree, dot,
                                 norm_specs, stack_specs)
from repro.models.transformer import cross_entropy, embed_specs, lm_head

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def cross_attention_specs(cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": ParamSpec((d, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((h * hd, d), ("heads", "embed2")),
        "bq": ParamSpec((h * hd,), ("heads",), init="zeros"),
        "bv": ParamSpec((kv * hd,), ("kv_heads",), init="zeros"),
    }


def encoder_layer_specs(cfg):
    return {"ln1": norm_specs(cfg), "attn": attn.attention_specs(cfg),
            "ln2": norm_specs(cfg), "mlp": mlp_mod.mlp_specs(cfg)}


def decoder_layer_specs(cfg):
    return {"ln1": norm_specs(cfg), "attn": attn.attention_specs(cfg),
            "ln_x": norm_specs(cfg), "xattn": cross_attention_specs(cfg),
            "ln2": norm_specs(cfg), "mlp": mlp_mod.mlp_specs(cfg)}


def whisper_specs(cfg):
    e = cfg.encdec
    return {
        "embed": embed_specs(cfg),                       # decoder token embed
        "enc_pos": ParamSpec((e.encoder_seq_len, cfg.d_model), (None, "embed"),
                             init="small"),
        "encoder": stack_specs(encoder_layer_specs(cfg), e.num_encoder_layers),
        "enc_norm": norm_specs(cfg),
        "decoder": stack_specs(decoder_layer_specs(cfg), cfg.num_layers),
        "final_norm": norm_specs(cfg),
    }


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def cross_attention_apply(cfg, p, x, enc_kv):
    """x: [B,S,D]; enc_kv: precomputed {"k","v"}: [B,T,KV,hd]."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    cd = x.dtype
    q = (dot(x, p["wq"], cd) + p["bq"].astype(cd)).reshape(B, S, H, hd)
    T = enc_kv["k"].shape[1]
    pos_q = jnp.zeros((S,), jnp.int32)      # cross-attn: no causal masking
    pos_k = jnp.zeros((T,), jnp.int32)
    out = attn.attention_core(q, enc_kv["k"], enc_kv["v"], pos_q, pos_k,
                              causal=False)
    return dot(out.reshape(B, S, H * hd), p["wo"], cd)


def encode(cfg, params, frames):
    """frames: [B,T,D] stub frame embeddings -> encoder output [B,T,D]."""
    cd = jnp.dtype(cfg.compute_dtype)
    T = frames.shape[1]
    x = frames.astype(cd) + params["enc_pos"][:T].astype(cd)
    pos = jnp.arange(T, dtype=jnp.int32)

    def body(x, lp):
        h = apply_norm(cfg, lp["ln1"], x)
        # bidirectional self-attention
        B, S, _ = h.shape
        hd = cfg.resolved_head_dim
        H, KV = cfg.num_heads, cfg.num_kv_heads
        q = dot(h, lp["attn"]["wq"], cd)
        k = dot(h, lp["attn"]["wk"], cd)
        v = dot(h, lp["attn"]["wv"], cd)
        if cfg.qkv_bias:
            q = q + lp["attn"]["bq"].astype(cd)
            k = k + lp["attn"]["bk"].astype(cd)
            v = v + lp["attn"]["bv"].astype(cd)
        q = q.reshape(B, S, H, hd)
        k = k.reshape(B, S, KV, hd)
        v = v.reshape(B, S, KV, hd)
        a = attn.attention_core(q, k, v, pos, pos, causal=False)
        x = x + dot(a.reshape(B, S, H * hd), lp["attn"]["wo"], cd)
        x = x + mlp_mod.mlp_apply(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
        return x, None

    fn = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(fn, x, params["encoder"])
    return apply_norm(cfg, params["enc_norm"], x)


def _enc_kv(cfg, lp, enc_out):
    cd = enc_out.dtype
    B, T, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = dot(enc_out, lp["xattn"]["wk"], cd).reshape(B, T, KV, hd)
    v = (dot(enc_out, lp["xattn"]["wv"], cd)
         + lp["xattn"]["bv"].astype(cd)).reshape(B, T, KV, hd)
    return {"k": k, "v": v}


def decoder_layer_apply(cfg, lp, x, positions, enc_out, cache=None):
    h = apply_norm(cfg, lp["ln1"], x)
    a, new_cache = attn.attention_apply(cfg, lp["attn"], h, positions, cache=cache)
    x = x + a
    h = apply_norm(cfg, lp["ln_x"], x)
    x = x + cross_attention_apply(cfg, lp["xattn"], h, _enc_kv(cfg, lp, enc_out))
    x = x + mlp_mod.mlp_apply(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
    return x, new_cache


def whisper_loss(cfg, params, batch):
    """batch: {"frames": [B,T,D], "tokens": [B,S], "labels": [B,S]}"""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    from repro.models.transformer import embed_lookup
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, lp):
        x, _ = decoder_layer_apply(cfg, lp, x, positions, enc_out)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(fn, x, params["decoder"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x)
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


def whisper_prefill(cfg, params, frames, tokens):
    """Encode + run decoder over the prompt, building self-attn caches.

    Returns (last_logits [B,V], {"self": caches, "enc": enc_out})."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    from repro.models.transformer import _fill_kv_cache, embed_lookup
    x = embed_lookup(cfg, params, tokens, cd)
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def body(x, lp):
        h = apply_norm(cfg, lp["ln1"], x)
        a, _ = attn.attention_apply(cfg, lp["attn"], h, positions)
        k = dot(h, lp["attn"]["wk"], cd)
        v = dot(h, lp["attn"]["wv"], cd)
        if cfg.qkv_bias:
            k = k + lp["attn"]["bk"].astype(cd)
            v = v + lp["attn"]["bv"].astype(cd)
        k = attn.apply_rope(k.reshape(B, S, KV, hd), positions, cfg.rope_theta)
        v = v.reshape(B, S, KV, hd)
        cache = _fill_kv_cache(k, v, positions, S)
        x = x + a
        h = apply_norm(cfg, lp["ln_x"], x)
        x = x + cross_attention_apply(cfg, lp["xattn"], h, _enc_kv(cfg, lp, enc_out))
        x = x + mlp_mod.mlp_apply(cfg, lp["mlp"], apply_norm(cfg, lp["ln2"], x))
        return x, cache

    x, caches = jax.lax.scan(body, x, params["decoder"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x[:, -1:])
    return logits[:, 0], {"self": caches, "enc": enc_out}


def whisper_decode(cfg, params, tokens, state):
    """One decode step; state = {"self": stacked caches, "enc": enc_out}."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    caches, enc_out = state["self"], state["enc"]
    B = tokens.shape[0]
    positions = jnp.full((B, 1), 0, jnp.int32) + caches["index"][0]
    from repro.models.transformer import embed_lookup
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, xs):
        lp, cache = xs
        x, new_cache = decoder_layer_apply(cfg, lp, x, positions, enc_out,
                                           cache=cache)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["decoder"], caches))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x)
    return logits[:, 0], {"self": new_caches, "enc": enc_out}
