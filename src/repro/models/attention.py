"""Attention substrate: GQA (full / sliding-window / local) + DeepSeek MLA.

Layouts: activations ``[batch, seq, d_model]``; heads ``[batch, seq, heads, head_dim]``.

Three execution paths:
  * ``attention_core``      — chunked online-softmax (double lax.scan), bounded
                              memory at 32k+ contexts; the traced default.
  * ``windowed_attention``  — per-q-block dynamic-slice of the KV range for
                              sliding-window/local attention (subquadratic).
  * ``decode_attend``       — single-step decode against a (ring-buffer) cache.

On TPU the Pallas flash kernel (``repro.kernels.flash_attention``) replaces
``attention_core`` when ``use_pallas=True`` (see transformer.py); the functions
here double as its reference semantics.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, apply_rope, dot
# the single quantizer for int8 KV pages (layouts depends only on jax, so
# this does not cross the serving layer's import boundary)
from repro.serving.layouts import SCALE_SUFFIX, quantize_kv

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attention_specs(cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    specs = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((h * hd, d), ("heads", "embed2")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h * hd,), ("heads",), init="zeros")
        specs["bk"] = ParamSpec((kv * hd,), ("kv_heads",), init="zeros")
        specs["bv"] = ParamSpec((kv * hd,), ("kv_heads",), init="zeros")
    return specs


def mla_specs(cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    specs = {
        # compressed kv + shared rope key
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "rank")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("rank",), init="zeros"),
        "w_uk": ParamSpec((m.kv_lora_rank, h * m.qk_nope_head_dim), ("rank", "heads")),
        "w_uv": ParamSpec((m.kv_lora_rank, h * m.v_head_dim), ("rank", "heads")),
        "wo": ParamSpec((h * m.v_head_dim, d), ("heads", "embed2")),
    }
    if m.q_lora_rank:
        specs["w_dq"] = ParamSpec((d, m.q_lora_rank), ("embed", "rank"))
        specs["q_norm"] = ParamSpec((m.q_lora_rank,), ("rank",), init="zeros")
        specs["w_uq"] = ParamSpec((m.q_lora_rank, h * qk_dim), ("rank", "heads"))
    else:
        specs["wq"] = ParamSpec((d, h * qk_dim), ("embed", "heads"))
    return specs


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (full / causal)
# ---------------------------------------------------------------------------

def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _project_qkv_rope(cfg, p, x, positions):
    """Shared GQA front half: q/k/v projections (+bias), head split, rope.

    x [B,S,D] -> q [B,S,H,hd], k/v [B,S,KV,hd] — used by both the slotted
    (``attention_apply``) and paged (``paged_attention_apply``) paths so
    projection changes can never diverge them.
    """
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    cd = x.dtype
    q = dot(x, p["wq"], cd)
    k = dot(x, p["wk"], cd)
    v = dot(x, p["wv"], cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = _split_heads(q, H, hd)
    k = _split_heads(k, KV, hd)
    v = _split_heads(v, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q: [B,qb,KV,G,hd]  k: [B,kb,KV,hd]  ->  [B,KV,G,qb,kb] (fp32)."""
    return jnp.einsum(
        "bqkgh,btkh->bkgqt", q, k,
        preferred_element_type=jnp.float32) * scale


def attention_core(q, k, v, q_pos, k_pos, *, causal: bool = True,
                   window: int = 0, q_block: int = 512, kv_block: int = 1024,
                   kv_valid: Optional[jax.Array] = None):
    """Memory-bounded attention via double scan with online softmax.

    q: [B,S,H,hd]; k,v: [B,T,KV,hd]; q_pos: [S] or [B,S]; k_pos: [T] or [B,T].
    window>0 additionally masks keys older than ``window`` positions.
    kv_valid: optional [B,T] bool mask of valid cache slots.
    Returns [B,S,H,hd] in q.dtype.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]                   # may differ from hd (MLA)
    G = H // KV
    scale = hd ** -0.5
    qb = min(q_block, S)
    kb = min(kv_block, T)
    # pad to block multiples
    S_p = -(-S // qb) * qb
    T_p = -(-T // kb) * kb
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None, :], (B, S))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None, :], (B, T))
    qp = jnp.pad(q, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, T_p - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, T_p - T), (0, 0), (0, 0)))
    qpos_p = jnp.pad(q_pos, ((0, 0), (0, S_p - S)), constant_values=-1)
    kpos_p = jnp.pad(k_pos, ((0, 0), (0, T_p - T)), constant_values=2**30)
    valid_p = (jnp.pad(kv_valid, ((0, 0), (0, T_p - T)), constant_values=False)
               if kv_valid is not None
               else jnp.pad(jnp.ones((B, T), bool), ((0, 0), (0, T_p - T)),
                            constant_values=False))

    nq, nk = S_p // qb, T_p // kb
    qp = qp.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)      # [nq,B,qb,KV,G,hd]
    kp = kp.reshape(B, nk, kb, KV, hd).transpose(1, 0, 2, 3, 4)           # [nk,B,kb,KV,hd]
    vp = vp.reshape(B, nk, kb, KV, hd_v).transpose(1, 0, 2, 3, 4)
    qpos_b = qpos_p.reshape(B, nq, qb).transpose(1, 0, 2)                  # [nq,B,qb]
    kpos_b = kpos_p.reshape(B, nk, kb).transpose(1, 0, 2)
    valid_b = valid_p.reshape(B, nk, kb).transpose(1, 0, 2)

    def q_step(_, q_in):
        q_i, qpos_i = q_in                                                # [B,qb,KV,G,hd], [B,qb]

        def kv_step(carry, kv_in):
            m, l, acc = carry
            k_j, v_j, kpos_j, valid_j = kv_in
            s = _gqa_scores(q_i, k_j, scale)                              # [B,KV,G,qb,kb]
            msk = valid_j[:, None, None, None, :]
            if causal:
                msk = msk & (kpos_j[:, None, None, None, :]
                             <= qpos_i[:, None, None, :, None])
            if window:
                msk = msk & (kpos_j[:, None, None, None, :]
                             > qpos_i[:, None, None, :, None] - window)
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kp, vp, kpos_b, valid_b))
        out = acc / jnp.maximum(l, 1e-30)[..., None]                       # [B,KV,G,qb,hd]
        return None, out.transpose(0, 3, 1, 2, 4)                          # [B,qb,KV,G,hd]

    _, out = jax.lax.scan(q_step, None, (qp, qpos_b))                      # [nq,B,qb,KV,G,hd]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S_p, H, hd_v)[:, :S]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Sliding-window attention: per-q-block KV slice (subquadratic)
# ---------------------------------------------------------------------------

def windowed_attention(q, k, v, q_pos, k_pos, *, window: int, q_block: int = 512):
    """Causal sliding-window attention; each q block attends a KV slice of
    length ``window + q_block`` ending at the block's last position.

    Shapes as in attention_core.  Assumes q and k cover the same contiguous
    positions (train/prefill self-attention).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qb = min(q_block, S)
    S_p = -(-S // qb) * qb
    span = window + qb
    if span >= T:  # window covers everything — fall back
        return attention_core(q, k, v, q_pos, k_pos, causal=True, window=window)
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None, :], (B, S))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None, :], (B, T))
    qp = jnp.pad(q, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    qpos_p = jnp.pad(q_pos, ((0, 0), (0, S_p - S)), constant_values=-1)
    nq = S_p // qb
    qp = qp.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qpos_b = qpos_p.reshape(B, nq, qb).transpose(1, 0, 2)
    starts = jnp.arange(nq) * qb + qb - span                               # may be <0; clamped

    def q_step(_, q_in):
        q_i, qpos_i, start = q_in
        start = jnp.clip(start, 0, T - span)
        k_j = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)         # [B,span,KV,hd]
        v_j = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        kpos_j = jax.lax.dynamic_slice_in_dim(k_pos, start, span, axis=1)  # [B,span]
        s = _gqa_scores(q_i, k_j, scale)                                   # [B,KV,G,qb,span]
        msk = (kpos_j[:, None, None, None, :] <= qpos_i[:, None, None, :, None])
        msk &= (kpos_j[:, None, None, None, :] > qpos_i[:, None, None, :, None] - window)
        s = jnp.where(msk, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(v_j.dtype), v_j,
                         preferred_element_type=jnp.float32)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, out = jax.lax.scan(q_step, None, (qp, qpos_b, starts))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S_p, H, hd)[:, :S]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode: ring-buffer KV cache
# ---------------------------------------------------------------------------

def init_cache(batch: int, cache_len: int, num_kv: int, head_dim: int, dtype):
    """Cache slots carry their absolute position (-1 = empty) so ring-buffer
    overwrites and windowing need no extra bookkeeping."""
    return {
        "k": jnp.zeros((batch, cache_len, num_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, num_kv, head_dim), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
        "index": jnp.zeros((), jnp.int32),       # absolute next position
    }


def cache_specs(batch: int, cache_len: int, num_kv: int, head_dim: int, dtype):
    import numpy as np
    S = jax.ShapeDtypeStruct
    return {
        "k": S((batch, cache_len, num_kv, head_dim), jnp.dtype(dtype)),
        "v": S((batch, cache_len, num_kv, head_dim), jnp.dtype(dtype)),
        "pos": S((cache_len,), jnp.int32),
        "index": S((), jnp.int32),
    }


def cache_update(cache, k_new, v_new):
    """Append one step (k_new/v_new: [B,1,KV,hd]) at ring slot index % len."""
    L = cache["k"].shape[1]
    slot = cache["index"] % L
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], cache["index"][None], slot, axis=0)
    return {"k": k, "v": v, "pos": pos, "index": cache["index"] + 1}


def decode_attend(q, cache, *, window: int = 0):
    """q: [B,1,H,hd] against the cache. Returns [B,1,H,hd]."""
    B, _, H, hd = q.shape
    KV = cache["k"].shape[2]
    G = H // KV
    scale = hd ** -0.5
    cur = cache["index"] - 1                      # position of the newest token
    kpos = cache["pos"]                           # [L]
    valid = kpos >= 0
    valid &= kpos <= cur
    if window:
        valid &= kpos > cur - window
    q_ = q.reshape(B, 1, KV, G, hd)
    s = _gqa_scores(q_, cache["k"], scale)        # [B,KV,G,1,L]
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(cache["v"].dtype), cache["v"],
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode: paged KV cache (global page pool shared by all slots)
# ---------------------------------------------------------------------------

def _paged_window(cfg) -> int:
    """Ring-layout window of a GQA family (0 = contiguous pages)."""
    return cfg.window if cfg.attn_kind in ("swa", "local") else 0


def paged_cache_update(kv, k_new, v_new, page_table, pos, *, window: int = 0):
    """Write one decode step's K/V into the shared page pool.

    kv: {"k","v"}: [P, ps, KV, hd] (one layer's pages); k_new/v_new
    [slots, 1, KV, hd]; page_table [slots, n] int32; pos [slots] int32 —
    token t of slot s lands in page ``page_table[s, t // ps]`` at offset
    ``t % ps`` (contiguous), or — ring layout, ``window > 0`` — in cell
    ``(t % window) // ps`` of the slot's ring table, same in-page offset
    (the pool guarantees ``ps | window``).  Slots without a request carry
    an all-trash table (page 0), so their writes clobber only the reserved
    trash page.

    Quantized (int8) pools carry a ``*_scale`` leaf per data leaf; the row
    is quantized once here (``quantize_kv``) and both the int8 row and its
    per-head scale scatter to the same (page, offset).
    """
    ps = kv["k"].shape[1]
    idx = pos % window if window else pos
    page = jnp.take_along_axis(page_table, (idx // ps)[:, None], axis=1)[:, 0]
    off = pos % ps
    out = {}
    for name, new in (("k", k_new), ("v", v_new)):
        row = new[:, 0]
        if name + SCALE_SUFFIX in kv:
            qrow, srow = quantize_kv(row)
            out[name] = kv[name].at[page, off].set(qrow)
            out[name + SCALE_SUFFIX] = \
                kv[name + SCALE_SUFFIX].at[page, off].set(srow)
        else:
            out[name] = kv[name].at[page, off].set(
                row.astype(kv[name].dtype))
    return out


def paged_latent_update(kv, ckv_new, krope_new, page_table, pos):
    """Latent-layout twin of ``paged_cache_update``: kv {"ckv": [P, ps, R],
    "krope": [P, ps, rp]}; ckv_new/krope_new [slots, 1, ·] (MLA decode
    caches the compressed latents, never materialized heads)."""
    ps = kv["ckv"].shape[1]
    page = jnp.take_along_axis(page_table, (pos // ps)[:, None], axis=1)[:, 0]
    off = pos % ps
    return {
        "ckv": kv["ckv"].at[page, off].set(
            ckv_new[:, 0].astype(kv["ckv"].dtype)),
        "krope": kv["krope"].at[page, off].set(
            krope_new[:, 0].astype(kv["krope"].dtype)),
    }


def _chunk_targets(page_ids, start, n_valid, S: int, ps: int,
                   window: int = 0):
    """(page, off) scatter targets for one prefill chunk of S bucket slots:
    token i holds absolute position ``start + i``; bucket padding
    (i >= n_valid) routes to the reserved trash page 0 so the fixed bucket
    shape never scatters garbage into held pages."""
    i = jnp.arange(S)
    pos = start + i
    idx = pos % window if window else pos
    blk = jnp.clip(idx // ps, 0, page_ids.shape[0] - 1)
    page = jnp.where(i < n_valid, page_ids[blk], 0)
    return page, pos % ps


def paged_prefill_write(kv, k_new, v_new, page_ids, start, n_valid, *,
                        window: int = 0):
    """Write one prefill chunk's K/V into the shared page pool.

    kv: {"k","v"}: [P, ps, KV, hd] (one layer's pages); k_new/v_new
    [1, S, KV, hd] (S = padded bucket length); page_ids [n] int32 — one
    request's page-table row; start / n_valid traced scalars.  Position
    mapping per ``_chunk_targets`` (contiguous or ring).  Quantized pools
    scatter int8 rows + per-head scales (see ``paged_cache_update``);
    padding rows land harmlessly in the trash page, scales included.
    """
    ps = kv["k"].shape[1]
    page, off = _chunk_targets(page_ids, start, n_valid, k_new.shape[1], ps,
                               window)
    out = {}
    for name, new in (("k", k_new), ("v", v_new)):
        rows = new[0]
        if name + SCALE_SUFFIX in kv:
            qrows, srows = quantize_kv(rows)
            out[name] = kv[name].at[page, off].set(qrows)
            out[name + SCALE_SUFFIX] = \
                kv[name + SCALE_SUFFIX].at[page, off].set(srows)
        else:
            out[name] = kv[name].at[page, off].set(
                rows.astype(kv[name].dtype))
    return out


def paged_latent_prefill_write(kv, ckv_new, krope_new, page_ids, start,
                               n_valid):
    """Latent-layout twin of ``paged_prefill_write``: ckv_new [1, S, R],
    krope_new [1, S, rp] into {"ckv", "krope"} pages (contiguous)."""
    ps = kv["ckv"].shape[1]
    page, off = _chunk_targets(page_ids, start, n_valid, ckv_new.shape[1],
                               ps)
    return {
        "ckv": kv["ckv"].at[page, off].set(
            ckv_new[0].astype(kv["ckv"].dtype)),
        "krope": kv["krope"].at[page, off].set(
            krope_new[0].astype(kv["krope"].dtype)),
    }


def paged_prefill_apply(cfg, p, x, positions, kv, page_ids, start, n_valid,
                        *, use_pallas: bool = False):
    """Prefill-chunk GQA self-attention directly against the page pool.

    x [1, S, D] — one request's chunk, padded to a power-of-two bucket;
    positions = start + arange(S); page_ids [n] the request's page-table
    row.  Contiguous layout: the chunk's K/V are written into the pool
    first (pages covering the cached prefix are *never* written: the chunk
    starts at ``start`` >= prefix length, and padding writes hit the trash
    page), then the chunk's queries attend causally over everything cached
    so far — shared prefix pages, earlier chunks, and the chunk itself.

    Ring layout (sliding-window/local): the chunk's writes *wrap onto*
    cells its own early queries still need, so the ring is consumed as a
    snapshot BEFORE the write and the chunk attends over [snapshot, chunk]
    with ring-arithmetic key positions; the sliding-window mask keeps
    every overwritten (out-of-window) snapshot cell out of the scores.
    The engine caps ring chunks at ``window`` tokens, so no two writes in
    one chunk collide.

    ``use_pallas`` dispatches the scalar-prefetched Pallas prefill kernels
    (``kernels.paged_attention``: HBM traffic ~ pages actually held,
    bucket-tail query rows skipped at grid level); the default is the
    traced whole-table gather through ``attention_core``.  Quantized (int8)
    pools route through the kernel family's paired oracle even with the
    kernels off — ref and kernel apply the *same* fused scale math (scales
    multiplied into the softmax accumulation, fp pages never materialized),
    which is what keeps quantized kernel-on vs kernel-off token-identical.

    Returns (out [1, S, D], new_kv).
    """
    from repro.kernels.paged_attention import ops as pa_ops
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    cd = x.dtype
    ps = kv["k"].shape[1]
    n = page_ids.shape[0]
    window = _paged_window(cfg)
    quantized = "k" + SCALE_SUFFIX in kv

    q, k, v = _project_qkv_rope(cfg, p, x, positions)
    if window:
        new_kv = paged_prefill_write(kv, k, v, page_ids, start, n_valid,
                                     window=window)
        if use_pallas or quantized:
            # snapshot semantics by construction: ``kv`` is the pre-write
            # pool, the chunk's own K/V ride along as separate fp operands
            # (freshly projected — only pool pages are quantized)
            out = pa_ops.paged_ring_prefill(
                q[0], kv["k"], kv["v"], k[0].astype(cd), v[0].astype(cd),
                page_ids, start, n_valid, window=window,
                k_scale=kv.get("k" + SCALE_SUFFIX),
                v_scale=kv.get("v" + SCALE_SUFFIX),
                use_kernel=use_pallas)[None]
        else:
            ring_k = kv["k"][page_ids].reshape(1, n * ps, *k.shape[2:])
            ring_v = kv["v"][page_ids].reshape(1, n * ps, *v.shape[2:])
            cur = start - 1
            i = jnp.arange(n * ps)
            ring_pos = cur - jnp.mod(cur - i, window)  # < 0 = never written
            kk = jnp.concatenate([ring_k.astype(cd), k], axis=1)
            vv = jnp.concatenate([ring_v.astype(cd), v], axis=1)
            k_pos = jnp.concatenate(
                [ring_pos[None, :], (start + jnp.arange(S))[None, :]],
                axis=1)
            kv_valid = jnp.concatenate(
                [(ring_pos >= 0)[None, :],
                 (jnp.arange(S) < n_valid)[None, :]], axis=1)
            out = attention_core(q, kk, vv, positions, k_pos, causal=True,
                                 window=window, q_block=cfg.attn_q_block,
                                 kv_block=cfg.attn_kv_block,
                                 kv_valid=kv_valid)
    else:
        new_kv = paged_prefill_write(kv, k, v, page_ids, start, n_valid)
        if use_pallas or quantized:
            out = pa_ops.paged_prefill(q[0], new_kv["k"], new_kv["v"],
                                       page_ids, start, n_valid,
                                       k_scale=new_kv.get("k" + SCALE_SUFFIX),
                                       v_scale=new_kv.get("v" + SCALE_SUFFIX),
                                       use_kernel=use_pallas)[None]
        else:
            # gather this request's pages into a contiguous [1, n*ps] view;
            # absolute key positions are the identity, validity =
            # written-so-far bound (trash entries in the table tail sit
            # past the bound, so they are never seen)
            kk = new_kv["k"][page_ids].reshape(1, n * ps, *k.shape[2:])
            vv = new_kv["v"][page_ids].reshape(1, n * ps, *v.shape[2:])
            k_pos = jnp.arange(n * ps)
            kv_valid = (k_pos < start + n_valid)[None, :]
            out = attention_core(q, kk.astype(cd), vv.astype(cd), positions,
                                 k_pos, causal=True,
                                 q_block=cfg.attn_q_block,
                                 kv_block=cfg.attn_kv_block,
                                 kv_valid=kv_valid)
    out = out.reshape(B, S, H * hd)
    return dot(out, p["wo"], cd), new_kv


def paged_attention_apply(cfg, p, x, positions, kv, page_table, lengths, *,
                          use_pallas: bool = False):
    """One batched decode step of GQA self-attention over a paged pool.

    x [slots, 1, D]; positions [slots, 1] (= lengths[:, None]); kv one
    layer's pages.  Unlike ``attention_apply`` (vmapped per slot over a
    private ring cache), this runs the whole slot batch against the shared
    pool.  Covers full attention (contiguous pages) and sliding-window /
    local attention (ring-wrapped window pages — the position mapping and
    window mask live in the kernel/ref).  Returns (out [slots, 1, D],
    new_kv).
    """
    from repro.kernels.paged_attention import ops as pa_ops
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    cd = x.dtype
    window = _paged_window(cfg)

    q, k, v = _project_qkv_rope(cfg, p, x, positions)
    new_kv = paged_cache_update(kv, k, v, page_table, lengths, window=window)
    out = pa_ops.paged_attention(q[:, 0], new_kv["k"], new_kv["v"],
                                 page_table, lengths + 1, window=window,
                                 k_scale=new_kv.get("k" + SCALE_SUFFIX),
                                 v_scale=new_kv.get("v" + SCALE_SUFFIX),
                                 use_kernel=use_pallas)
    out = out[:, None].reshape(B, S, H * hd)
    return dot(out, p["wo"], cd), new_kv


def paged_mla_attention_apply(cfg, p, x, positions, kv, page_table, lengths,
                              *, use_pallas: bool = False):
    """One batched decode step of absorbed MLA over latent pages.

    x [slots, 1, D]; kv {"ckv": [P, ps, R], "krope": [P, ps, rp]} — one
    layer's latent pages.  The math mirrors ``mla_apply``'s decode path
    (scores in the latent space; cache stays compressed), the storage is
    the shared page pool.  Returns (out [slots, 1, D], new_kv)."""
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.models.common import rms_norm
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cd = x.dtype
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    dkv = dot(x, p["w_dkv"], cd)                         # [B,1,rank+rope]
    ckv, krope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(krope[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]
    q_nope, q_rope = _mla_q(cfg, p, x, positions, cd)
    new_kv = paged_latent_update(kv, ckv, krope, page_table, lengths)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim).astype(cd)
    # absorb: q' = q_nope @ W_uk^T -> latent-space queries [B,1,H,rank]
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk,
                       preferred_element_type=jnp.float32).astype(cd)
    o_lat = pa_ops.paged_mla_attention(
        q_lat[:, 0], q_rope[:, 0], new_kv["ckv"], new_kv["krope"],
        page_table, lengths + 1, scale=scale, use_kernel=use_pallas)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim).astype(cd)
    out = jnp.einsum("bshr,rhv->bshv", o_lat[:, None].astype(cd), w_uv,
                     preferred_element_type=jnp.float32)
    out = out.astype(cd).reshape(B, S, H * m.v_head_dim)
    return dot(out, p["wo"], cd), new_kv


def paged_mla_prefill_apply(cfg, p, x, positions, kv, page_ids, start,
                            n_valid, *, use_pallas: bool = False):
    """Prefill-chunk MLA attention directly against latent pages.

    The chunk's (normalized) latents are written into the pool.  The
    traced default then — to match the slotted prefill's numerics
    (``mla_apply``'s *expanded* path) — materializes per-head K/V from
    the gathered latents and attends causally over prefix + chunk.
    ``use_pallas`` dispatches the absorbed Pallas prefill kernel instead
    (``paged_mla_prefill``: queries absorbed through W_uk, pages stream
    as compressed ckv/krope, the latent output up-projects through W_uv
    — the same math as the absorbed decode path, so HBM traffic is the
    compressed cache).  Contiguous layout only (MLA is full causal
    attention).  Returns (out [1, S, D], new_kv)."""
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.models.common import rms_norm
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cd = x.dtype
    ps = kv["ckv"].shape[1]
    n = page_ids.shape[0]

    dkv = dot(x, p["w_dkv"], cd)                          # [1,S,rank+rope]
    ckv, krope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(krope[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]
    new_kv = paged_latent_prefill_write(kv, ckv, krope, page_ids, start,
                                        n_valid)
    q_nope, q_rope = _mla_q(cfg, p, x, positions, cd)
    if use_pallas:
        scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
        w_uk = p["w_uk"].reshape(m.kv_lora_rank, H,
                                 m.qk_nope_head_dim).astype(cd)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk,
                           preferred_element_type=jnp.float32).astype(cd)
        o_lat = pa_ops.paged_mla_prefill(
            q_lat[0], q_rope[0], new_kv["ckv"], new_kv["krope"], page_ids,
            start, n_valid, scale=scale, use_kernel=True)
        w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim).astype(cd)
        out = jnp.einsum("shr,rhv->shv", o_lat.astype(cd), w_uv,
                         preferred_element_type=jnp.float32)[None]
        out = out.astype(cd)
    else:
        ckv_all = new_kv["ckv"][page_ids].reshape(1, n * ps,
                                                  m.kv_lora_rank).astype(cd)
        kr_all = new_kv["krope"][page_ids].reshape(
            1, n * ps, m.qk_rope_head_dim).astype(cd)
        k_nope = dot(ckv_all, p["w_uk"], cd).reshape(1, n * ps, H,
                                                     m.qk_nope_head_dim)
        vv = dot(ckv_all, p["w_uv"], cd).reshape(1, n * ps, H, m.v_head_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                      (1, n * ps, H, m.qk_rope_head_dim))],
            axis=-1)
        k_pos = jnp.arange(n * ps)
        kv_valid = (k_pos < start + n_valid)[None, :]
        out = attention_core(q, k, vv, positions, k_pos, causal=True,
                             q_block=cfg.attn_q_block,
                             kv_block=cfg.attn_kv_block, kv_valid=kv_valid)
    out = out.reshape(B, S, H * m.v_head_dim)
    return dot(out, p["wo"], cd), new_kv


# ---------------------------------------------------------------------------
# Full GQA block apply (projections + rope + core/window/decode dispatch)
# ---------------------------------------------------------------------------

def attention_apply(cfg, p, x, positions, *, cache=None, use_pallas: bool = False):
    """Self-attention for train/prefill (cache=None) or one decode step.

    Returns (out [B,S,D], new_cache_or_None).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    cd = x.dtype

    q, k, v = _project_qkv_rope(cfg, p, x, positions)

    window = cfg.window if cfg.attn_kind in ("swa", "local") else 0
    if cache is None:
        if use_pallas:
            from repro.kernels.flash_attention import ops as fa_ops
            core = functools.partial(fa_ops.flash_attention,
                                     causal=True, window=window)
        elif window and S > 2 * window:
            core = lambda q, k, v: windowed_attention(
                q, k, v, positions, positions, window=window,
                q_block=cfg.attn_q_block)
        else:
            core = lambda q, k, v: attention_core(
                q, k, v, positions, positions, causal=True, window=window,
                q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
        if cfg.attn_remat and not use_pallas:
            # flash-style custom-VJP backward: saves only (O, logsumexp) and
            # recomputes score blocks in the backward — kills the stacked
            # probability residuals that dominate the traced path's HBM term
            # (nested jax.checkpoint does NOT achieve this: the scan
            # transpose re-stacks them; see EXPERIMENTS.md §Perf).
            from repro.models.flash_vjp import flash_attention_vjp
            core = functools.partial(
                flash_attention_vjp, causal=True, window=window,
                q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
        out = core(q, k, v)
        new_cache = None
    else:
        new_cache = cache_update(cache, k, v)
        out = decode_attend(q, new_cache, window=window)
    out = out.reshape(B, S, H * hd)
    return dot(out, p["wo"], cd), new_cache


# ---------------------------------------------------------------------------
# MLA apply (DeepSeek-V2): compressed KV cache, expanded for train/prefill,
# absorbed projections for decode.
# ---------------------------------------------------------------------------

def mla_init_cache(batch: int, cache_len: int, cfg, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }


def mla_cache_specs(batch: int, cache_len: int, cfg, dtype):
    m = cfg.mla
    S = jax.ShapeDtypeStruct
    return {
        "ckv": S((batch, cache_len, m.kv_lora_rank), jnp.dtype(dtype)),
        "krope": S((batch, cache_len, m.qk_rope_head_dim), jnp.dtype(dtype)),
        "pos": S((cache_len,), jnp.int32),
        "index": S((), jnp.int32),
    }


def _mla_q(cfg, p, x, positions, cd):
    m = cfg.mla
    H = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        from repro.models.common import rms_norm
        cq = rms_norm(dot(x, p["w_dq"], cd), p["q_norm"], cfg.norm_eps)
        q = dot(cq, p["w_uq"], cd)
    else:
        q = dot(x, p["wq"], cd)
    q = q.reshape(*x.shape[:2], H, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(cfg, p, x, positions, *, cache=None):
    """Returns (out [B,S,D], new_cache_or_None)."""
    from repro.models.common import rms_norm
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cd = x.dtype
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    dkv = dot(x, p["w_dkv"], cd)                                  # [B,S,rank+rope]
    ckv, krope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    q_nope, q_rope = _mla_q(cfg, p, x, positions, cd)

    if cache is None:
        # expanded path: materialize per-head K/V from the latent
        k_nope = dot(ckv, p["w_uk"], cd).reshape(B, S, H, m.qk_nope_head_dim)
        vv = dot(ckv, p["w_uv"], cd).reshape(B, S, H, m.v_head_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
            axis=-1)
        out = attention_core(q, k, vv, positions, positions, causal=True)
        out = out.reshape(B, S, H * m.v_head_dim)
        return dot(out, p["wo"], cd), None

    # absorbed decode path: score in the latent space (cache stays compressed)
    L = cache["ckv"].shape[1]
    slot = cache["index"] % L
    new_cache = {
        "ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), slot, axis=1),
        "krope": jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], krope.astype(cache["krope"].dtype), slot, axis=1),
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], cache["index"][None], slot, axis=0),
        "index": cache["index"] + 1,
    }
    cur = new_cache["index"] - 1
    valid = (new_cache["pos"] >= 0) & (new_cache["pos"] <= cur)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim).astype(cd)
    # absorb: q' = q_nope @ W_uk^T  -> latent-space queries [B,1,H,rank]
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk, preferred_element_type=jnp.float32)
    s = jnp.einsum("bshr,btr->bhst", q_lat.astype(cd), new_cache["ckv"],
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bshn,btn->bhst", q_rope, new_cache["krope"],
                    preferred_element_type=jnp.float32)
    s = jnp.where(valid[None, None, None, :], s * scale, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    # output in latent space, then up-project via W_uv
    o_lat = jnp.einsum("bhst,btr->bshr", pr.astype(cd), new_cache["ckv"],
                       preferred_element_type=jnp.float32).astype(cd)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim).astype(cd)
    out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv, preferred_element_type=jnp.float32)
    out = out.astype(cd).reshape(B, 1, H * m.v_head_dim)
    return dot(out, p["wo"], cd), new_cache
