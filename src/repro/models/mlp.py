"""Feed-forward substrate: SwiGLU / GEGLU / plain MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, activation, dot

GATED = ("swiglu", "geglu")


def mlp_specs(cfg, d_ff: int = 0):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act in GATED:
        return {
            "w_gate": ParamSpec((d, f), ("embed", "mlp")),
            "w_up": ParamSpec((d, f), ("embed", "mlp")),
            "w_down": ParamSpec((f, d), ("mlp", "embed2")),
        }
    return {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "b_up": ParamSpec((f,), ("mlp",), init="zeros"),
        "w_down": ParamSpec((f, d), ("mlp", "embed2")),
        "b_down": ParamSpec((d,), ("embed2",), init="zeros"),
    }


def mlp_apply(cfg, p, x):
    act = activation(cfg.act)
    cd = x.dtype
    if cfg.act in GATED:
        h = act(dot(x, p["w_gate"], cd)) * dot(x, p["w_up"], cd)
        return dot(h, p["w_down"], cd)
    h = act(dot(x, p["w_up"], cd) + p["b_up"].astype(cd))
    return dot(h, p["w_down"], cd) + p["b_down"].astype(cd)
