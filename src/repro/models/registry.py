"""Architecture registry: one uniform interface over all model families.

A ``ModelBundle`` exposes, per architecture:
  * ``specs``                 — ParamSpec tree (init / dry-run / shardings)
  * ``loss_fn(params,batch)``       — train_* cells
  * ``prefill_fn(params,**inputs)`` — prefill_* cells
  * ``decode_fn(params,tokens,state)`` — decode_* / long_* cells
  * ``*_input_specs(shape)``  — ShapeDtypeStruct stand-ins per assignment
    (modality frontends are stubs: whisper gets frame embeddings, pixtral
    gets patch embeddings)

Decode-path contracts are *typed*: ``TrainStepContract``, ``ServeContract``
and ``PagedServeContract`` below are the Protocols a family implements, and
``ModelBundle.capabilities()`` reports which of them it declares.  Runtime
consumers (``repro.serving``, ``repro.api``) dispatch on the declared
capability set — never on ``is None`` probes against individual fields — so
an unsupported workload fails with one clear error at session-load time.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import (Any, Callable, Dict, FrozenSet, Optional, Protocol,
                    Tuple, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import common, transformer, rglru, rwkv6, whisper, pixtral
from repro.serving.layouts import KVLayout, layout_for


S_ = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Decode-path contracts (typed Protocols; see ModelBundle.capabilities)
# ---------------------------------------------------------------------------

@runtime_checkable
class TrainStepContract(Protocol):
    """Sequential training semantics: ``loss_fn(params, batch) -> scalar``.

    ``batch`` matches ``train_input_specs``; the runtime injects broadcast
    and all-reduce around it (``TransparentTrainer``)."""

    def __call__(self, params, batch) -> jax.Array: ...


@runtime_checkable
class ServeContract(Protocol):
    """Engine-facing prefill: ``(params, tokens, *, cache_len) -> (last_logits,
    state)``.

    ``state`` must match ``init_decode_state(batch, cache_len)`` leaf-for-
    leaf so the engine can insert it into its slot pool without reshaping.
    Paired with ``decode_fn`` for the slotted decode path.

    Families that additionally declare ``"bucketed_prefill"`` accept a
    traced ``n_valid`` keyword: ``tokens`` is then a padded power-of-two
    bucket whose tail past ``n_valid`` is masked out of the cache and the
    logits — the engine's compile-count bound under ragged prompt
    lengths."""

    def __call__(self, params, tokens, *, cache_len: int) -> Tuple[Any, Any]: ...


@runtime_checkable
class PagedServeContract(Protocol):
    """Paged batched decode against the shared page pool:
    ``(params, tokens, state, *, use_pallas=False) -> (logits [slots, V],
    pages)`` with ``state = {"pages": {leaf: [L, P, ps, ...]},
    "page_table": [slots, n] int32, "pos": [slots] int32}``.

    The page leaves are the family's ``KVLayout`` (per-head "k"/"v" for
    GQA — contiguous for full attention, ring-wrapped for swa/local —
    latent "ckv"/"krope" for MLA).  The engine builds the pool from
    ``init_decode_state(1, page_size)``.  ``use_pallas`` selects the
    Pallas paged-attention kernels (TPU) vs the traced jnp references
    (CPU)."""

    def __call__(self, params, tokens, state, *,
                 use_pallas: bool = False) -> Tuple[Any, Any]: ...


@runtime_checkable
class PagedPrefillContract(Protocol):
    """Chunked prefill straight into the page pool (the prefix-cache path):
    ``(params, tokens, state, *, use_pallas=False) -> (logits [1, V],
    pages)`` with ``state = {"pages": {"k","v"}: [L, P, ps, KV, hd],
    "page_table": [n] int32, "start": scalar, "n_valid": scalar}``.

    ``tokens`` [1, S] is one request's uncached suffix chunk padded to a
    power-of-two bucket; ``start`` is how many tokens (shared prefix pages +
    earlier chunks) are already cached, ``n_valid`` how many of the chunk's
    tokens are real.  The function writes the chunk's K/V into the pool and
    attends causally over prefix + chunk, so the engine can admit a request
    whose prompt prefix is already cached without re-running its FLOPs.
    Declaring this contract is what flips on the engine's ``prefix_serve``
    capability (see ``ServeConfig.enable_prefix_cache``)."""

    def __call__(self, params, tokens, state, *,
                 use_pallas: bool = False) -> Tuple[Any, Any]: ...


@runtime_checkable
class PagedVerifyContract(Protocol):
    """Speculative-decode verify forward (the spec-decode path):
    ``(params, tokens, state, *, use_pallas=False) -> (logits [S, V],
    pages)`` with the same ``state`` as ``PagedPrefillContract``.

    ``tokens`` [1, S] holds one slot's last committed token followed by
    its draft tokens (fixed width ``spec_tokens + 1``; the tail past
    ``n_valid`` is masked into the trash page).  Unlike the prefill
    contract the head runs over *every* position: row ``j`` predicts
    sequence index ``start + 1 + j``, which is exactly what the engine
    replays its per-request sampler over to find the accepted draft
    prefix.  Declaring this contract flips on the engine's
    ``spec_serve`` capability (see ``ServeConfig.enable_spec``)."""

    def __call__(self, params, tokens, state, *,
                 use_pallas: bool = False) -> Tuple[Any, Any]: ...


#: capability names a bundle may declare (see ModelBundle.capabilities)
CAPABILITIES = ("train", "serve", "paged_serve", "prefix_serve",
                "spec_serve", "bucketed_prefill")


@dataclass
class ModelBundle:
    cfg: ModelConfig
    specs: Any
    loss_fn: TrainStepContract            # (params, batch) -> scalar loss
    prefill_fn: Optional[Callable]        # (params, **inputs) -> (logits, state)
    decode_fn: Optional[Callable]         # (params, tokens, state) -> (logits, state)
    train_input_specs: Callable           # (ShapeConfig) -> dict of SDS
    prefill_input_specs: Callable
    decode_state_specs: Callable          # (ShapeConfig) -> state SDS tree
    init_decode_state: Callable           # (batch, seq_len) -> state arrays
    # Serving decode-path contract (repro.serving): a ``ServeContract``
    # prefill that emits a decode state sized for an engine-owned KV slot of
    # capacity ``cache_len`` (token budget = prompt + generated).  None for
    # families the engine does not serve yet (encdec / vlm frontends need
    # per-request modality inputs).
    serve_prefill_fn: Optional[ServeContract] = None
    # Paged decode contract (``PagedServeContract``).  Present exactly when
    # ``kv_layout`` is — the layout seam (``repro.serving.layouts``) is the
    # single capability authority; recurrent families (RG-LRU conv/hidden
    # and RWKV wkv state are O(1) per slot — nothing to page) have no
    # layout and stay slotted.
    paged_decode_fn: Optional[PagedServeContract] = None
    # Paged prefill contract (``PagedPrefillContract``): chunked prefill
    # into the page pool, the mechanism behind prefix caching and chunked
    # prefill.  Same layout gate as paged_decode_fn.
    paged_prefill_fn: Optional[PagedPrefillContract] = None
    # Speculative-decode verify contract (``PagedVerifyContract``): the
    # all-position-logits sibling of paged_prefill_fn.  Same layout gate.
    paged_verify_fn: Optional[PagedVerifyContract] = None
    # Physical page layout of the decode cache (None = slotted only); the
    # engine hands this to ``PagedKVCachePool`` and validates page-size /
    # window compatibility against it.
    kv_layout: Optional[KVLayout] = None
    # True when serve_prefill_fn accepts a traced ``n_valid`` (masked bucket
    # tail).  Recurrent families advance their state token-by-token, so
    # tail padding would corrupt it; ring-caching families (swa/local) wrap
    # padding onto valid slots in the *slotted* prefill cache — both keep
    # exact-length slotted prefills (the paged chunk path buckets every
    # layout: its tails route to the trash page).
    masked_prefill: bool = False

    def capabilities(self) -> FrozenSet[str]:
        """Declared decode-path contracts (subset of ``CAPABILITIES``).

        ``"train"``            — ``loss_fn`` implements ``TrainStepContract``;
        ``"serve"``            — ``serve_prefill_fn`` (``ServeContract``) +
                                 ``decode_fn`` drive the slotted engine path;
        ``"paged_serve"``      — ``paged_decode_fn`` (``PagedServeContract``)
                                 additionally drives the paged KV pool;
        ``"prefix_serve"``     — ``paged_prefill_fn``
                                 (``PagedPrefillContract``) enables prefix-
                                 cache page sharing + chunked prefill;
        ``"spec_serve"``       — ``paged_verify_fn``
                                 (``PagedVerifyContract``) enables n-gram
                                 speculative decoding;
        ``"bucketed_prefill"`` — serve_prefill_fn takes ``n_valid`` (the
                                 engine may pad prompts to power-of-two
                                 buckets with masked tails).
        """
        caps = set()
        if self.loss_fn is not None:
            caps.add("train")
        if self.serve_prefill_fn is not None and self.decode_fn is not None:
            caps.add("serve")
            if self.masked_prefill:
                caps.add("bucketed_prefill")
        if self.paged_decode_fn is not None:
            caps.add("paged_serve")
            if self.paged_prefill_fn is not None:
                caps.add("prefix_serve")
            if self.paged_verify_fn is not None:
                caps.add("spec_serve")
        return frozenset(caps)

    def param_structs(self):
        return common.param_shape_structs(self.specs)

    def init_params(self, key):
        return common.init_params(self.specs, key)


def _lm_train_specs(cfg, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": S_((B, S), jnp.int32), "labels": S_((B, S), jnp.int32)}


def _lm_prefill_specs(cfg, shape: ShapeConfig):
    return {"tokens": S_((shape.global_batch, shape.seq_len), jnp.int32)}


def _lm_decode_tokens(shape: ShapeConfig):
    return {"tokens": S_((shape.global_batch, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# Family builders
# ---------------------------------------------------------------------------

def _build_lm(cfg: ModelConfig) -> ModelBundle:
    # the layout seam decides paged capability — never an attn_kind probe
    layout = layout_for(cfg)
    return ModelBundle(
        cfg=cfg,
        specs=transformer.lm_specs(cfg),
        loss_fn=functools.partial(transformer.lm_loss, cfg),
        prefill_fn=lambda params, tokens: transformer.lm_prefill(
            cfg, params, tokens,
            cache_len=transformer.decode_cache_len(cfg, tokens.shape[1])),
        decode_fn=functools.partial(transformer.lm_decode, cfg),
        train_input_specs=functools.partial(_lm_train_specs, cfg),
        prefill_input_specs=functools.partial(_lm_prefill_specs, cfg),
        decode_state_specs=lambda shape: transformer.decode_cache_specs(
            cfg, shape.global_batch, shape.seq_len),
        init_decode_state=functools.partial(
            lambda cfg, b, s: transformer.init_decode_caches(cfg, b, s), cfg),
        # serving prefill routes MoE drop-free per token (moe_dropless):
        # capacity truncation would couple tokens across bucket widths /
        # chunk boundaries / prefix skips and break token identity
        serve_prefill_fn=lambda params, tokens, *, cache_len, n_valid=None:
            transformer.lm_prefill(
                cfg, params, tokens,
                cache_len=transformer.decode_cache_len(cfg, cache_len),
                n_valid=n_valid, moe_dropless=True),
        paged_decode_fn=(functools.partial(transformer.lm_paged_decode, cfg)
                         if layout is not None else None),
        paged_prefill_fn=(functools.partial(transformer.lm_paged_prefill,
                                            cfg)
                          if layout is not None else None),
        paged_verify_fn=(functools.partial(transformer.lm_paged_verify, cfg)
                         if layout is not None else None),
        kv_layout=layout,
        # masked bucket tails need the *slotted* prefill cache to hold the
        # whole bucket (no ring wrap): true for the contiguous layouts,
        # false for ring (window) caches, where padding would wrap onto
        # valid slots — those get bucketing through the paged chunk path
        # instead (tails route to the trash page)
        masked_prefill=(layout is not None and not layout.ring),
    )


def _build_rg(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        specs=rglru.rg_specs(cfg),
        loss_fn=functools.partial(rglru.rg_loss, cfg),
        prefill_fn=lambda params, tokens: rglru.rg_prefill(cfg, params, tokens),
        decode_fn=functools.partial(rglru.rg_decode, cfg),
        train_input_specs=functools.partial(_lm_train_specs, cfg),
        prefill_input_specs=functools.partial(_lm_prefill_specs, cfg),
        decode_state_specs=lambda shape: rglru.rg_state_specs(
            cfg, shape.global_batch, shape.seq_len),
        init_decode_state=functools.partial(
            lambda cfg, b, s: rglru.rg_init_states(cfg, b, s), cfg),
        serve_prefill_fn=lambda params, tokens, *, cache_len: rglru.rg_prefill(
            cfg, params, tokens, cache_len=cache_len),
    )


def _build_rwkv(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        specs=rwkv6.rwkv_specs(cfg),
        loss_fn=functools.partial(rwkv6.rwkv_loss, cfg),
        prefill_fn=lambda params, tokens: rwkv6.rwkv_prefill(cfg, params, tokens),
        decode_fn=functools.partial(rwkv6.rwkv_decode, cfg),
        train_input_specs=functools.partial(_lm_train_specs, cfg),
        prefill_input_specs=functools.partial(_lm_prefill_specs, cfg),
        decode_state_specs=lambda shape: rwkv6.rwkv_state_specs(
            cfg, shape.global_batch),
        init_decode_state=functools.partial(
            lambda cfg, b, s: rwkv6.rwkv_init_states(cfg, b), cfg),
        # recurrent state is O(1) in sequence length: capacity is a no-op
        serve_prefill_fn=lambda params, tokens, *, cache_len: rwkv6.rwkv_prefill(
            cfg, params, tokens),
    )


def _build_whisper(cfg: ModelConfig) -> ModelBundle:
    e = cfg.encdec

    def train_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        return {
            "frames": S_((B, e.encoder_seq_len, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
            "tokens": S_((B, S), jnp.int32),
            "labels": S_((B, S), jnp.int32),
        }

    def prefill_specs(shape: ShapeConfig):
        s = train_specs(shape)
        return {"frames": s["frames"], "tokens": s["tokens"]}

    def state_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        from repro.models.attention import cache_specs
        one = cache_specs(B, S, cfg.num_kv_heads, cfg.resolved_head_dim,
                          cfg.compute_dtype)
        stacked = jax.tree.map(
            lambda x: S_((cfg.num_layers,) + x.shape, x.dtype), one)
        return {"self": stacked,
                "enc": S_((B, e.encoder_seq_len, cfg.d_model),
                          jnp.dtype(cfg.compute_dtype))}

    def init_state(b, s):
        from repro.models.attention import init_cache
        one = init_cache(b, s, cfg.num_kv_heads, cfg.resolved_head_dim,
                         jnp.dtype(cfg.compute_dtype))
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), one)
        return {"self": stacked,
                "enc": jnp.zeros((b, e.encoder_seq_len, cfg.d_model),
                                 jnp.dtype(cfg.compute_dtype))}

    return ModelBundle(
        cfg=cfg,
        specs=whisper.whisper_specs(cfg),
        loss_fn=functools.partial(whisper.whisper_loss, cfg),
        prefill_fn=lambda params, frames, tokens: whisper.whisper_prefill(
            cfg, params, frames, tokens),
        decode_fn=functools.partial(whisper.whisper_decode, cfg),
        train_input_specs=train_specs,
        prefill_input_specs=prefill_specs,
        decode_state_specs=state_specs,
        init_decode_state=init_state,
    )


def _build_pixtral(cfg: ModelConfig) -> ModelBundle:
    v = cfg.vlm
    n_img = v.num_image_tokens

    def train_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        s_text = max(S - n_img, 1)
        return {
            "tokens": S_((B, s_text), jnp.int32),
            "patches": S_((B, n_img, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
            "labels": S_((B, s_text), jnp.int32),
        }

    def prefill_specs(shape: ShapeConfig):
        s = train_specs(shape)
        return {"tokens": s["tokens"], "patches": s["patches"]}

    return ModelBundle(
        cfg=cfg,
        specs=pixtral.pixtral_specs(cfg),
        loss_fn=functools.partial(pixtral.pixtral_loss, cfg),
        prefill_fn=lambda params, tokens, patches: pixtral.pixtral_prefill(
            cfg, params, tokens, patches),
        decode_fn=functools.partial(transformer.lm_decode, cfg),
        train_input_specs=train_specs,
        prefill_input_specs=prefill_specs,
        decode_state_specs=lambda shape: transformer.decode_cache_specs(
            cfg, shape.global_batch, shape.seq_len),
        init_decode_state=functools.partial(
            lambda cfg, b, s: transformer.init_decode_caches(cfg, b, s), cfg),
    )


_BUILDERS = {
    "dense": _build_lm,
    "moe": _build_lm,
    "hybrid": _build_rg,
    "ssm": _build_rwkv,
    "encdec": _build_whisper,
    "vlm": _build_pixtral,
}


def build(cfg: ModelConfig) -> ModelBundle:
    cfg.validate()
    return _BUILDERS[cfg.family](cfg)


# ---------------------------------------------------------------------------
# Analytic parameter / FLOP accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    bundle = build(cfg)
    total = 0
    for leaf in common.spec_leaves(bundle.specs):
        n = int(np.prod(leaf.shape))
        total += n
    if active_only and cfg.moe is not None:
        m = cfg.moe
        # each expert's FFN params (gate+up+down), counted per layer
        per_expert = 3 * cfg.d_model * m.d_ff_expert
        n_layers = cfg.num_layers
        inactive = (m.num_experts - m.top_k) * per_expert * n_layers
        total -= inactive
    return total


def embed_param_count(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    return n


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step: 6·N·T (train), 2·N·T (prefill), 2·N·B (decode),
    with N = active params excluding the input embedding table (lm_head kept)."""
    n_active = count_params(cfg, active_only=True)
    n_active -= cfg.vocab_size * cfg.d_model          # input table is a gather
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: 1 new token/seq
