"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern (RG-2B): (recurrent, recurrent, attention) repeating over 26
layers = 8 scanned super-blocks + 2 tail recurrent layers.

The RG-LRU recurrence (Griffin eq. 3-4):
    r_t = sigmoid(W_a x_t + b_a)                      (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                      (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)            (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill evaluates the recurrence with an *associative scan* (log-depth,
TPU-friendly); the Pallas kernel (kernels/rglru_scan) is the hand-tiled
alternative; decode is the one-step recurrence.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (ParamSpec, apply_norm, cast_tree, dot,
                                 maybe_wsc, norm_specs, stack_specs)
from repro.models.transformer import (cross_entropy, embed_lookup, embed_specs,
                                      lm_head)

P = jax.sharding.PartitionSpec
C_EXP = 8.0


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _lru_width(cfg) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def recurrent_block_specs(cfg):
    d, w = cfg.d_model, _lru_width(cfg)
    cw = cfg.rglru.conv1d_width
    return {
        "ln": norm_specs(cfg),
        "w_x": ParamSpec((d, w), ("embed", "lru")),        # recurrence branch
        "w_gate": ParamSpec((d, w), ("embed", "lru")),     # gelu gate branch
        "conv_w": ParamSpec((cw, w), ("window", "lru"), init="small"),
        "conv_b": ParamSpec((w,), ("lru",), init="zeros"),
        "lru_lambda": ParamSpec((w,), ("lru",), init="normal"),
        # square gate matrices: shard the OUTPUT dim (matches u's sharding)
        "lru_wa": ParamSpec((w, w), ("lru_in", "lru")),
        "lru_ba": ParamSpec((w,), ("lru",), init="zeros"),
        "lru_wx": ParamSpec((w, w), ("lru_in", "lru")),
        "lru_bx": ParamSpec((w,), ("lru",), init="zeros"),
        "w_out": ParamSpec((w, d), ("lru", "embed2")),
        "ln2": norm_specs(cfg),
        "mlp": mlp_mod.mlp_specs(cfg),
    }


def attention_block_specs(cfg):
    return {"ln": norm_specs(cfg), "attn": attn.attention_specs(cfg),
            "ln2": norm_specs(cfg), "mlp": mlp_mod.mlp_specs(cfg)}


def super_block_specs(cfg):
    return {"rec1": recurrent_block_specs(cfg),
            "rec2": recurrent_block_specs(cfg),
            "attn": attention_block_specs(cfg)}


def rg_specs(cfg):
    n_super, n_tail = divmod(cfg.num_layers, 3)
    specs = {
        "embed": embed_specs(cfg),
        "blocks": stack_specs(super_block_specs(cfg), n_super),
        "final_norm": norm_specs(cfg),
    }
    if n_tail:
        specs["tail"] = stack_specs(recurrent_block_specs(cfg), n_tail)
    if not cfg.tie_embeddings:
        from repro.models.transformer import head_specs
        specs["lm_head"] = head_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def _lru_gates(p, x):
    """x: [B,S,W] fp32 -> (log_a, gated_input) both [B,S,W] fp32."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["lru_wa"].astype(jnp.float32) + p["lru_ba"])
    i = jax.nn.sigmoid(x32 @ p["lru_wx"].astype(jnp.float32) + p["lru_bx"])
    log_a = -C_EXP * jax.nn.softplus(p["lru_lambda"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * x32)
    return log_a, gated


def rg_lru_scan(p, x, h0=None, use_pallas: bool = False):
    """Associative-scan evaluation. x: [B,S,W]; h0: [B,W] or None.

    Returns (y [B,S,W] in x.dtype, h_last [B,W] fp32).
    """
    log_a, gated = _lru_gates(p, x)
    if h0 is not None:
        # fold the incoming state in as a virtual step 0
        log_a = jnp.concatenate([jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
        gated = jnp.concatenate([h0[:, None, :].astype(jnp.float32), gated], axis=1)
    if use_pallas:
        from repro.kernels.rglru_scan import ops as lru_ops
        h = lru_ops.lru_scan(log_a, gated)
    else:
        def combine(c1, c2):
            (la1, g1), (la2, g2) = c1, c2
            return la1 + la2, g1 * jnp.exp(la2) + g2
        _, h = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


def rg_lru_step(p, x_t, h_prev):
    """One decode step. x_t: [B,W]; h_prev: [B,W] fp32."""
    log_a, gated = _lru_gates(p, x_t[:, None, :])
    h = jnp.exp(log_a[:, 0]) * h_prev + gated[:, 0]
    return h.astype(x_t.dtype), h


def causal_conv1d(p, x, state=None):
    """Depthwise causal conv, width cw. x: [B,S,W]; state: [B,cw-1,W] or None.

    Returns (y [B,S,W], new_state [B,cw-1,W])."""
    cw = p["conv_w"].shape[0]
    B, S, W = x.shape
    if state is None:
        state = jnp.zeros((B, cw - 1, W), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                      # [B,S+cw-1,W]
    y = sum(xp[:, i:i + S] * p["conv_w"][i].astype(x.dtype) for i in range(cw))
    y = y + p["conv_b"].astype(x.dtype)
    return y, xp[:, -(cw - 1):]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def recurrent_block_apply(cfg, p, x, state=None, use_pallas=False):
    """state: {"conv": [B,cw-1,W], "h": [B,W] fp32} or None (train/prefill
    from zero state).  Returns (x, new_state_or_None)."""
    cd = x.dtype
    h_in = apply_norm(cfg, p["ln"], x)
    u = dot(h_in, p["w_x"], cd)
    u = maybe_wsc(u, P(None, None, "model"))
    gate = jax.nn.gelu(dot(h_in, p["w_gate"], cd))
    conv_state = state["conv"] if state is not None else None
    u, new_conv = causal_conv1d(p, u, conv_state)
    if state is None:
        y, h_last = rg_lru_scan(p, u, use_pallas=use_pallas)
        new_state = {"conv": new_conv, "h": h_last}
    else:
        y, h_last = rg_lru_step(p, u[:, 0], state["h"])
        y = y[:, None, :]
        new_state = {"conv": new_conv, "h": h_last}
    x = x + dot(y * gate, p["w_out"], cd)
    h2 = apply_norm(cfg, p["ln2"], x)
    x = x + mlp_mod.mlp_apply(cfg, p["mlp"], h2)
    return x, new_state


def attention_block_apply(cfg, p, x, positions, cache=None, use_pallas=False):
    h = apply_norm(cfg, p["ln"], x)
    a, new_cache = attn.attention_apply(cfg, p["attn"], h, positions,
                                        cache=cache, use_pallas=use_pallas)
    x = x + a
    h2 = apply_norm(cfg, p["ln2"], x)
    x = x + mlp_mod.mlp_apply(cfg, p["mlp"], h2)
    return x, new_cache


def super_block_apply(cfg, p, x, positions, states=None, use_pallas=False):
    s1 = states["rec1"] if states else None
    s2 = states["rec2"] if states else None
    sa = states["attn"] if states else None
    x, n1 = recurrent_block_apply(cfg, p["rec1"], x, s1, use_pallas)
    x, n2 = recurrent_block_apply(cfg, p["rec2"], x, s2, use_pallas)
    x, na = attention_block_apply(cfg, p["attn"], x, positions, sa, use_pallas)
    if states is None:
        return x, None
    return x, {"rec1": n1, "rec2": n2, "attn": na}


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------

def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn)


def rg_forward(cfg, params, tokens, use_pallas=False):
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_lookup(cfg, params, tokens, cd)

    block_fn = _remat(cfg, functools.partial(
        super_block_apply, cfg, positions=positions, use_pallas=use_pallas))

    def body(x, bp):
        x, _ = block_fn(bp, x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    if "tail" in params:
        tail_fn = _remat(cfg, functools.partial(
            recurrent_block_apply, cfg, use_pallas=use_pallas))

        def tbody(x, tp):
            x, _ = tail_fn(tp, x)
            return x, None
        x, _ = jax.lax.scan(tbody, x, params["tail"])
    return apply_norm(cfg, params["final_norm"], x)


def rg_loss(cfg, params, batch, *, use_pallas=False):
    params = cast_tree(params, cfg.compute_dtype)
    x = rg_forward(cfg, params, batch["tokens"], use_pallas=use_pallas)
    logits = lm_head(cfg, params, x)
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


# --- decode ----------------------------------------------------------------

def _rec_state_init(cfg, batch):
    w, cw = _lru_width(cfg), cfg.rglru.conv1d_width
    cd = jnp.dtype(cfg.compute_dtype)
    return {"conv": jnp.zeros((batch, cw - 1, w), cd),
            "h": jnp.zeros((batch, w), jnp.float32)}


def rg_init_states(cfg, batch: int, seq_len: int):
    n_super, n_tail = divmod(cfg.num_layers, 3)
    cd = jnp.dtype(cfg.compute_dtype)
    win = cfg.rglru.attention_window
    cache = attn.init_cache(batch, min(seq_len, win), cfg.num_kv_heads,
                            cfg.resolved_head_dim, cd)
    one = {"rec1": _rec_state_init(cfg, batch),
           "rec2": _rec_state_init(cfg, batch), "attn": cache}
    states = {"blocks": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_super,) + x.shape).copy(), one)}
    if n_tail:
        states["tail"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_tail,) + x.shape).copy(),
            _rec_state_init(cfg, batch))
    return states


def rg_state_specs(cfg, batch: int, seq_len: int):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        jax.eval_shape(lambda: rg_init_states(cfg, batch, seq_len)))


def rg_decode(cfg, params, tokens, states):
    """tokens [B,1] + states -> (logits [B,V], new_states)."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    index = states["blocks"]["attn"]["index"][0]
    positions = jnp.full((B, 1), 0, jnp.int32) + index
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, xs):
        bp, st = xs
        x, new_st = super_block_apply(cfg, bp, x, positions, st)
        return x, new_st

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], states["blocks"]))
    new_states = {"blocks": new_blocks}
    if "tail" in params:
        def tbody(x, xs):
            tp, st = xs
            x, new_st = recurrent_block_apply(cfg, tp, x, st)
            return x, new_st
        x, new_tail = jax.lax.scan(tbody, x, (params["tail"], states["tail"]))
        new_states["tail"] = new_tail
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x)
    return logits[:, 0], new_states


def rg_prefill(cfg, params, tokens, *, cache_len: int = 0, use_pallas=False):
    """Prefill: full forward while materializing final recurrent states and
    the local-attention ring caches.  Returns (last_logits [B,V], states).

    ``cache_len`` sets the ring-cache capacity (still bounded by the
    attention window); 0 keeps the prompt-length cache of the demo path.
    The serving engine passes its pool capacity so prefill states drop
    straight into a KV slot without reshaping."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    win = cfg.rglru.attention_window
    x = embed_lookup(cfg, params, tokens, cd)

    def rec_prefill(p, x, use_pallas=use_pallas):
        h_in = apply_norm(cfg, p["ln"], x)
        u = dot(h_in, p["w_x"], cd)
        gate = jax.nn.gelu(dot(h_in, p["w_gate"], cd))
        u, conv_state = causal_conv1d(p, u)
        y, h_last = rg_lru_scan(p, u, use_pallas=use_pallas)
        x = x + dot(y * gate, p["w_out"], cd)
        x = x + mlp_mod.mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        return x, {"conv": conv_state, "h": h_last}

    def attn_prefill(p, x):
        from repro.models.transformer import _fill_kv_cache
        h = apply_norm(cfg, p["ln"], x)
        a, _ = attn.attention_apply(cfg, p["attn"], h, positions,
                                    use_pallas=use_pallas)
        k = dot(h, p["attn"]["wk"], cd).reshape(B, S, cfg.num_kv_heads, -1)
        v = dot(h, p["attn"]["wv"], cd).reshape(B, S, cfg.num_kv_heads, -1)
        k = attn.apply_rope(k, positions, cfg.rope_theta)
        cache = _fill_kv_cache(k, v, positions, min(cache_len or S, win))
        x = x + a
        x = x + mlp_mod.mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x))
        return x, cache

    def body(x, bp):
        x, s1 = rec_prefill(bp["rec1"], x)
        x, s2 = rec_prefill(bp["rec2"], x)
        x, ca = attn_prefill(bp["attn"], x)
        return x, {"rec1": s1, "rec2": s2, "attn": ca}

    x, blocks = jax.lax.scan(body, x, params["blocks"])
    states = {"blocks": blocks}
    if "tail" in params:
        def tbody(x, tp):
            return rec_prefill(tp, x)
        x, tail = jax.lax.scan(tbody, x, params["tail"])
        states["tail"] = tail
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x[:, -1:])
    return logits[:, 0], states
