"""Paper-native CNNs: AlexNet (Table II) and a TinyCNN for the Fig. 7
sequential-vs-distributed loss-equivalence experiment.

The paper evaluates AlexNet/GoogLeNet/InceptionV3/ResNet50 on ImageNet.
AlexNet is implemented faithfully (conv stack + FC head; LRN replaced by
identity — a documented deviation, standard in modern reproductions).
The technique under test (transparent DP) is architecture-agnostic, so the
TinyCNN exercises the identical code path at laptop scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, cast_tree

P = jax.sharding.PartitionSpec


def conv_spec(kh, kw, cin, cout):
    return {
        "w": ParamSpec((kh, kw, cin, cout), (None, None, None, "mlp"),
                       fan_in_axis=-2),
        "b": ParamSpec((cout,), ("mlp",), init="zeros"),
    }


def dense_spec(fin, fout, shard_out=True):
    return {
        "w": ParamSpec((fin, fout), ("embed", "mlp" if shard_out else None)),
        "b": ParamSpec((fout,), ("mlp" if shard_out else None,), init="zeros"),
    }


def conv2d(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"].astype(x.dtype)


def maxpool(x, k=3, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


# ---------------------------------------------------------------------------
# AlexNet (input 224x224x3, 1000 classes)
# ---------------------------------------------------------------------------

def alexnet_specs(num_classes: int = 1000):
    return {
        "c1": conv_spec(11, 11, 3, 96),
        "c2": conv_spec(5, 5, 96, 256),
        "c3": conv_spec(3, 3, 256, 384),
        "c4": conv_spec(3, 3, 384, 384),
        "c5": conv_spec(3, 3, 384, 256),
        "f6": dense_spec(256 * 6 * 6, 4096),
        "f7": dense_spec(4096, 4096),
        "f8": dense_spec(4096, num_classes, shard_out=False),
    }


def alexnet_forward(params, images):
    x = images
    x = maxpool(jax.nn.relu(conv2d(params["c1"], x, 4, "VALID")))
    x = maxpool(jax.nn.relu(conv2d(params["c2"], x)))
    x = jax.nn.relu(conv2d(params["c3"], x))
    x = jax.nn.relu(conv2d(params["c4"], x))
    x = maxpool(jax.nn.relu(conv2d(params["c5"], x)))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["f6"]["w"].astype(x.dtype) + params["f6"]["b"].astype(x.dtype))
    x = jax.nn.relu(x @ params["f7"]["w"].astype(x.dtype) + params["f7"]["b"].astype(x.dtype))
    return x @ params["f8"]["w"].astype(x.dtype) + params["f8"]["b"].astype(x.dtype)


# ---------------------------------------------------------------------------
# TinyCNN (16x16x3, for CPU-scale equivalence runs)
# ---------------------------------------------------------------------------

def tinycnn_specs(num_classes: int = 10):
    return {
        "c1": conv_spec(3, 3, 3, 16),
        "c2": conv_spec(3, 3, 16, 32),
        "f1": dense_spec(32 * 4 * 4, 64),
        "f2": dense_spec(64, num_classes, shard_out=False),
    }


def tinycnn_forward(params, images):
    x = images
    x = maxpool(jax.nn.relu(conv2d(params["c1"], x)), 2, 2)
    x = maxpool(jax.nn.relu(conv2d(params["c2"], x)), 2, 2)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["f1"]["w"].astype(x.dtype) + params["f1"]["b"].astype(x.dtype))
    return x @ params["f2"]["w"].astype(x.dtype) + params["f2"]["b"].astype(x.dtype)


def cnn_loss(forward_fn, params, batch, num_classes: int):
    """batch: {"images": [B,H,W,C], "labels": [B]} -> mean CE (fp32)."""
    logits = forward_fn(params, batch["images"]).astype(jnp.float32)
    onehot = jax.nn.one_hot(batch["labels"], num_classes)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))
