"""RWKV-6 "Finch": attention-free time mixing with data-dependent decay.

Per layer:  time-mix (WKV6 recurrence over an outer-product state) +
channel-mix (squared-relu MLP with token-shift lerp).

WKV6 per head (state S in R^{hd x hd}, decay w_t data-dependent — the Finch
hallmark, arXiv:2404.05892):
    out_t = r_t^T (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

Traced/ref path runs the recurrence with lax.scan over time; the Pallas
kernel (kernels/rwkv6_wkv) is the block-chunked TPU version; decode is the
one-step form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import (ParamSpec, apply_norm, cast_tree, dot,
                                 layer_norm, norm_specs, stack_specs)
from repro.models.transformer import (cross_entropy, embed_lookup, embed_specs,
                                      head_specs, lm_head)


def _heads(cfg):
    hd = cfg.rwkv.head_size
    return cfg.d_model // hd, hd


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def time_mix_specs(cfg):
    d = cfg.d_model
    h, hd = _heads(cfg)
    r = cfg.rwkv.decay_lora
    return {
        "ln": norm_specs(cfg),
        "mu_r": ParamSpec((d,), ("embed",), init="small"),
        "mu_k": ParamSpec((d,), ("embed",), init="small"),
        "mu_v": ParamSpec((d,), ("embed",), init="small"),
        "mu_g": ParamSpec((d,), ("embed",), init="small"),
        "mu_w": ParamSpec((d,), ("embed",), init="small"),
        "w_r": ParamSpec((d, d), ("embed", "heads")),
        "w_k": ParamSpec((d, d), ("embed", "heads")),
        "w_v": ParamSpec((d, d), ("embed", "heads")),
        "w_g": ParamSpec((d, d), ("embed", "heads")),
        "w_o": ParamSpec((d, d), ("heads", "embed2")),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": ParamSpec((d,), ("embed",), init="small"),
        "decay_a": ParamSpec((d, r), ("embed", "rank"), init="small"),
        "decay_b": ParamSpec((r, d), ("rank", "embed2"), init="small"),
        "bonus_u": ParamSpec((h, hd), (None, None), init="small"),
        # per-head output groupnorm
        "gn_scale": ParamSpec((d,), ("embed",), init="ones"),
        "gn_bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def channel_mix_specs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln": norm_specs(cfg),
        "mu_k": ParamSpec((d,), ("embed",), init="small"),
        "mu_r": ParamSpec((d,), ("embed",), init="small"),
        "w_k": ParamSpec((d, f), ("embed", "mlp")),
        "w_v": ParamSpec((f, d), ("mlp", "embed2")),
        "w_r": ParamSpec((d, d), ("embed", "embed2")),
    }


def layer_specs(cfg):
    return {"tm": time_mix_specs(cfg), "cm": channel_mix_specs(cfg)}


def rwkv_specs(cfg):
    specs = {
        "embed": embed_specs(cfg),
        "layers": stack_specs(layer_specs(cfg), cfg.num_layers),
        "final_norm": norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = head_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------

def wkv_scan(r, k, v, w, u, s0=None, use_pallas: bool = False):
    """r,k,v,w: [B,S,H,hd] (w = decay in (0,1), fp32); u: [H,hd].

    Returns (out [B,S,H,hd] fp32, s_last [B,H,hd,hd] fp32)."""
    B, S, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    if use_pallas:
        from repro.kernels.rwkv6_wkv import ops as wkv_ops
        return wkv_ops.wkv6(r, k, v, w, u, s0)
    rf, kf, vf, wf = (t.astype(jnp.float32).transpose(1, 0, 2, 3)
                      for t in (r, k, v, w))                     # [S,B,H,hd]

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]               # [B,H,hd,hd]
        out = jnp.einsum("bhi,bhij->bhj", r_t,
                         s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, out

    s_last, out = jax.lax.scan(step, s0, (rf, kf, vf, wf))
    return out.transpose(1, 0, 2, 3), s_last


def wkv_step(r, k, v, w, u, s):
    """One decode step; r,k,v,w: [B,H,hd]."""
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhi,bhij->bhj", r, s + u[None, :, :, None] * kv)
    s = w[..., :, None] * s + kv
    return out, s


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _lerp(x, z, mu):
    return x + (z - x) * mu.astype(x.dtype)


def _shift(x, last=None):
    """z_t = x_{t-1}; last: [B,D] carries state across decode steps."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)


def _decay(p, xw):
    """Data-dependent per-channel decay in (0,1), fp32."""
    x32 = xw.astype(jnp.float32)
    lora = jnp.tanh(x32 @ p["decay_a"].astype(jnp.float32)) @ p["decay_b"].astype(jnp.float32)
    return jnp.exp(-jnp.exp(p["decay_w0"].astype(jnp.float32) + lora))


def time_mix_apply(cfg, p, x, state=None, use_pallas=False):
    """state: {"x": [B,D], "s": [B,H,hd,hd]} or None. -> (y, new_state)."""
    cd = x.dtype
    B, S, D = x.shape
    H, hd = _heads(cfg)
    xin = apply_norm(cfg, p["ln"], x)
    z = _shift(xin, state["x"] if state is not None else None)
    r = dot(_lerp(xin, z, p["mu_r"]), p["w_r"], cd).reshape(B, S, H, hd)
    k = dot(_lerp(xin, z, p["mu_k"]), p["w_k"], cd).reshape(B, S, H, hd)
    v = dot(_lerp(xin, z, p["mu_v"]), p["w_v"], cd).reshape(B, S, H, hd)
    g = jax.nn.silu(dot(_lerp(xin, z, p["mu_g"]), p["w_g"], cd))
    w = _decay(p, _lerp(xin, z, p["mu_w"])).reshape(B, S, H, hd)
    u = p["bonus_u"].astype(jnp.float32)

    if state is None:
        out, s_last = wkv_scan(r, k, v, w, u, use_pallas=use_pallas)
        new_state = None if state is None else state
        new_state = {"x": xin[:, -1], "s": s_last}
    else:
        out, s_last = wkv_step(r[:, 0].astype(jnp.float32),
                               k[:, 0].astype(jnp.float32),
                               v[:, 0].astype(jnp.float32),
                               w[:, 0], u, state["s"])
        out = out[:, None]
        new_state = {"x": xin[:, -1], "s": s_last}

    # per-head group norm on the flattened head outputs
    out = out.reshape(B, S, H, hd)
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 64e-5)
    out = out.reshape(B, S, D).astype(cd)
    out = out * p["gn_scale"].astype(cd) + p["gn_bias"].astype(cd)
    y = dot(out * g, p["w_o"], cd)
    return x + y, new_state


def channel_mix_apply(cfg, p, x, state=None):
    cd = x.dtype
    xin = apply_norm(cfg, p["ln"], x)
    z = _shift(xin, state if state is not None else None)
    k = dot(_lerp(xin, z, p["mu_k"]), p["w_k"], cd)
    k = jnp.square(jax.nn.relu(k))
    kv = dot(k, p["w_v"], cd)
    rr = jax.nn.sigmoid(dot(_lerp(xin, z, p["mu_r"]), p["w_r"], cd))
    return x + rr * kv, xin[:, -1]


def layer_apply(cfg, p, x, state=None, use_pallas=False):
    tm_state = state["tm"] if state is not None else None
    cm_state = state["cm"] if state is not None else None
    x, new_tm = time_mix_apply(cfg, p["tm"], x, tm_state, use_pallas)
    x, new_cm = channel_mix_apply(cfg, p["cm"], x, cm_state)
    if state is None:
        return x, None
    return x, {"tm": new_tm, "cm": new_cm}


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def rwkv_forward(cfg, params, tokens, use_pallas=False):
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(cfg, params, tokens, cd)
    fn = functools.partial(layer_apply, cfg, use_pallas=use_pallas)
    if cfg.remat != "none":
        fn = jax.checkpoint(fn)

    def body(x, lp):
        x, _ = fn(lp, x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(cfg, params["final_norm"], x)


def rwkv_loss(cfg, params, batch, *, use_pallas=False):
    params = cast_tree(params, cfg.compute_dtype)
    x = rwkv_forward(cfg, params, batch["tokens"], use_pallas=use_pallas)
    logits = lm_head(cfg, params, x)
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


def rwkv_init_states(cfg, batch: int):
    H, hd = _heads(cfg)
    cd = jnp.dtype(cfg.compute_dtype)
    one = {
        "tm": {"x": jnp.zeros((batch, cfg.d_model), cd),
               "s": jnp.zeros((batch, H, hd, hd), jnp.float32)},
        "cm": jnp.zeros((batch, cfg.d_model), cd),
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), one)


def rwkv_state_specs(cfg, batch: int):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        jax.eval_shape(lambda: rwkv_init_states(cfg, batch)))


def rwkv_decode(cfg, params, tokens, states):
    """tokens [B,1] -> (logits [B,V], new_states). Position-free (no rope)."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, xs):
        lp, st = xs
        return layer_apply(cfg, lp, x, st)

    x, new_states = jax.lax.scan(body, x, (params["layers"], states))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x)
    return logits[:, 0], new_states


def rwkv_prefill(cfg, params, tokens, *, use_pallas=False):
    """Full forward materializing final states. -> (last_logits, states)."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, lp):
        xin = apply_norm(cfg, lp["tm"]["ln"], x)
        # time mix with state collection
        B, S, D = x.shape
        H, hd = _heads(cfg)
        z = _shift(xin)
        r = dot(_lerp(xin, z, lp["tm"]["mu_r"]), lp["tm"]["w_r"], cd).reshape(B, S, H, hd)
        k = dot(_lerp(xin, z, lp["tm"]["mu_k"]), lp["tm"]["w_k"], cd).reshape(B, S, H, hd)
        v = dot(_lerp(xin, z, lp["tm"]["mu_v"]), lp["tm"]["w_v"], cd).reshape(B, S, H, hd)
        g = jax.nn.silu(dot(_lerp(xin, z, lp["tm"]["mu_g"]), lp["tm"]["w_g"], cd))
        w = _decay(lp["tm"], _lerp(xin, z, lp["tm"]["mu_w"])).reshape(B, S, H, hd)
        u = lp["tm"]["bonus_u"].astype(jnp.float32)
        out, s_last = wkv_scan(r, k, v, w, u, use_pallas=use_pallas)
        tm_state = {"x": xin[:, -1], "s": s_last}
        out = out.reshape(B, S, H, hd)
        mu_ = jnp.mean(out, axis=-1, keepdims=True)
        var = jnp.var(out, axis=-1, keepdims=True)
        out = ((out - mu_) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, D).astype(cd)
        out = out * lp["tm"]["gn_scale"].astype(cd) + lp["tm"]["gn_bias"].astype(cd)
        x = x + dot(out * g, lp["tm"]["w_o"], cd)
        x, cm_state = channel_mix_apply(cfg, lp["cm"], x)
        return x, {"tm": tm_state, "cm": cm_state}

    x, states = jax.lax.scan(body, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x[:, -1:])
    return logits[:, 0], states
