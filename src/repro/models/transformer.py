"""Decoder-only LM assembly: embeddings + scanned layers + head.

Covers the dense (GQA/SWA), MoE, and MLA families.  Hybrid (RG-LRU), SSM
(RWKV6), enc-dec (whisper) and VLM (pixtral) live in their own modules but
reuse the helpers here.

Three entry points lowered by the launcher:
  * ``lm_loss``       — train_* cells (tokens -> scalar loss)
  * ``lm_prefill``    — prefill_* cells (tokens -> last-token logits + caches)
  * ``lm_decode``     — decode_* / long_* cells (1 token + caches -> logits)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import (ParamSpec, apply_norm, cast_tree, dot,
                                 maybe_wsc, norm_specs, stack_specs)

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def embed_specs(cfg):
    return ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab_table", "embed"),
                     init="embed")


def head_specs(cfg):
    return ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))


def decoder_layer_specs(cfg):
    a = attn.mla_specs(cfg) if cfg.attn_kind == "mla" else attn.attention_specs(cfg)
    ff = moe_mod.moe_specs(cfg) if cfg.moe is not None else mlp_mod.mlp_specs(cfg)
    return {"ln1": norm_specs(cfg), "attn": a, "ln2": norm_specs(cfg), "ff": ff}


def lm_specs(cfg):
    specs = {
        "embed": embed_specs(cfg),
        "layers": stack_specs(decoder_layer_specs(cfg), cfg.num_layers),
        "final_norm": norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = head_specs(cfg)
    return specs


# ---------------------------------------------------------------------------
# Layer apply
# ---------------------------------------------------------------------------

def decoder_layer_apply(cfg, p, x, positions, cache=None, use_pallas=False):
    """Returns (x, new_cache, aux_loss)."""
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.attn_kind == "mla":
        a, new_cache = attn.mla_apply(cfg, p["attn"], h, positions, cache=cache)
    else:
        a, new_cache = attn.attention_apply(cfg, p["attn"], h, positions,
                                            cache=cache, use_pallas=use_pallas)
    x = x + a
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        f, aux = moe_mod.moe_apply(cfg, p["ff"], h)
    else:
        f, aux = mlp_mod.mlp_apply(cfg, p["ff"], h), jnp.zeros((), jnp.float32)
    x = x + f
    x = maybe_wsc(x, P(None, None, None))
    return x, new_cache, aux


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_lookup(cfg, params, tokens, compute_dtype):
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    return x


def lm_head(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dot(x, w, x.dtype)
    return maybe_wsc(logits, P(None, None, "model"))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def lm_forward(cfg, params, tokens, *, collect_cache: bool = False,
               cache_len: int = 0, use_pallas: bool = False, n_valid=None,
               moe_dropless: bool = False):
    """tokens [B,S] -> (logits [B,S,V], caches_or_None, aux).

    ``n_valid`` (traced scalar, cache-collection path only) marks a masked
    bucket tail: tokens at positions >= n_valid are padding — their cache
    slots carry pos = -1 (decode never attends them) and the cache index is
    n_valid, so one compiled shape serves every prompt length in a bucket.
    Causality already keeps tail padding out of the valid tokens' outputs.

    ``moe_dropless`` (serving prefill): route MoE per token with no
    capacity truncation, so the serving engine's token-identity guarantee
    survives bucket widths / chunk boundaries / prefix-cache skips (see
    ``moe_apply``).  Training and the roofline prefill cells keep GShard
    capacity semantics.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_lookup(cfg, params, tokens, cd)
    x = maybe_wsc(x, P(None, None, None))

    layer_fn = _remat(cfg, functools.partial(
        decoder_layer_apply, cfg, use_pallas=use_pallas))

    if not collect_cache:
        def body(carry, lp):
            x, aux = carry
            x, _, a = layer_fn(lp, x, positions)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        caches = None
    else:
        kv = cfg.num_kv_heads
        hd = cfg.resolved_head_dim

        def body(carry, lp):
            x, aux = carry
            h = apply_norm(cfg, lp["ln1"], x)
            if cfg.attn_kind == "mla":
                # run expanded attention, stash latent cache
                a, _ = attn.mla_apply(cfg, lp["attn"], h, positions)
                from repro.models.common import rms_norm
                dkv = dot(h, lp["attn"]["w_dkv"], cd)
                ckv, krope = jnp.split(dkv, [cfg.mla.kv_lora_rank], axis=-1)
                ckv = rms_norm(ckv, lp["attn"]["kv_norm"], cfg.norm_eps)
                krope = attn.apply_rope(
                    krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
                cache_y = _fill_latent_cache(ckv, krope, positions, cache_len,
                                             n_valid)
            else:
                a, new_c = attn.attention_apply(cfg, lp["attn"], h, positions,
                                                use_pallas=use_pallas)
                # recompute k/v once more is avoided: attention_apply already
                # projected them; for cache collection we project again below —
                # cheap relative to attention itself, and keeps apply pure.
                q_unused = None
                k = dot(h, lp["attn"]["wk"], cd)
                v = dot(h, lp["attn"]["wv"], cd)
                if cfg.qkv_bias:
                    k = k + lp["attn"]["bk"].astype(cd)
                    v = v + lp["attn"]["bv"].astype(cd)
                k = k.reshape(B, S, kv, hd)
                v = v.reshape(B, S, kv, hd)
                k = attn.apply_rope(k, positions, cfg.rope_theta)
                cache_y = _fill_kv_cache(k, v, positions, cache_len, n_valid)
            x = x + a
            h2 = apply_norm(cfg, lp["ln2"], x)
            if cfg.moe is not None:
                # bucketed serving prefill: padding must not consume
                # expert capacity, and serving routes drop-free per token
                # (see moe_apply's n_valid / per_token)
                f, a2 = moe_mod.moe_apply(cfg, lp["ff"], h2, n_valid=n_valid,
                                          per_token=moe_dropless)
            else:
                f, a2 = mlp_mod.mlp_apply(cfg, lp["ff"], h2), jnp.zeros((), jnp.float32)
            return (x + f, aux + a2), cache_y

        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"])

    x = apply_norm(cfg, params["final_norm"], x)
    return x, caches, aux


def _fill_kv_cache(k, v, positions, cache_len: int, n_valid=None):
    """Build a ring cache from prefill k/v (keep the last cache_len tokens).

    With ``n_valid`` (masked bucket tail; requires S <= cache_len so padding
    cannot ring-wrap onto valid slots) padding entries carry pos = -1 and
    ``index`` = n_valid — decode masks them exactly like never-written slots.
    """
    B, S, KV, hd = k.shape
    L = min(cache_len, S) if cache_len else S
    ks = k[:, S - L:]
    vs = v[:, S - L:]
    pos = positions[S - L:]
    slots = pos % (cache_len or S)
    Lc = cache_len or S
    ck = jnp.zeros((B, Lc, KV, hd), k.dtype).at[:, slots].set(ks)
    cv = jnp.zeros((B, Lc, KV, hd), v.dtype).at[:, slots].set(vs)
    if n_valid is None:
        cpos = jnp.full((Lc,), -1, jnp.int32).at[slots].set(pos)
        index = jnp.asarray(S, jnp.int32)
    else:
        cpos = jnp.full((Lc,), -1, jnp.int32).at[slots].set(
            jnp.where(pos < n_valid, pos, -1))
        index = jnp.asarray(n_valid, jnp.int32)
    return {"k": ck, "v": cv, "pos": cpos, "index": index}


def _fill_latent_cache(ckv, krope, positions, cache_len: int, n_valid=None):
    B, S, R = ckv.shape
    Lc = cache_len or S
    L = min(Lc, S)
    pos = positions[S - L:]
    slots = pos % Lc
    c1 = jnp.zeros((B, Lc, R), ckv.dtype).at[:, slots].set(ckv[:, S - L:])
    c2 = jnp.zeros((B, Lc, krope.shape[-1]), krope.dtype).at[:, slots].set(krope[:, S - L:])
    if n_valid is None:
        cpos = jnp.full((Lc,), -1, jnp.int32).at[slots].set(pos)
        index = jnp.asarray(S, jnp.int32)
    else:
        cpos = jnp.full((Lc,), -1, jnp.int32).at[slots].set(
            jnp.where(pos < n_valid, pos, -1))
        index = jnp.asarray(n_valid, jnp.int32)
    return {"ckv": c1, "krope": c2, "pos": cpos, "index": index}


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, vocab: int):
    """Mean token cross-entropy in fp32 (labels == -100 are masked)."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    labels = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def lm_loss(cfg, params, batch, *, use_pallas: bool = False):
    """batch: {"tokens": [B,S], "labels": [B,S]} -> scalar fp32 loss."""
    params = cast_tree(params, cfg.compute_dtype)
    x, _, aux = lm_forward(cfg, params, batch["tokens"], use_pallas=use_pallas)
    logits = lm_head(cfg, params, x)
    loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss


def lm_prefill(cfg, params, tokens, *, cache_len: int = 0,
               use_pallas: bool = False, n_valid=None,
               moe_dropless: bool = False):
    """tokens [B,S] -> (last_logits [B,V], caches).

    ``n_valid`` (traced): S is a padded power-of-two bucket and only the
    first n_valid tokens are real — the cache masks the tail and the
    returned logits are the n_valid-th token's, so one compiled shape
    serves every prompt length that rounds up to the same bucket.
    ``moe_dropless`` selects the serving engine's per-token (no capacity
    truncation) MoE routing — see lm_forward.
    """
    params = cast_tree(params, cfg.compute_dtype)
    x, caches, _ = lm_forward(cfg, params, tokens, collect_cache=True,
                              cache_len=cache_len or tokens.shape[1],
                              use_pallas=use_pallas, n_valid=n_valid,
                              moe_dropless=moe_dropless)
    if n_valid is None:
        last = x[:, -1:]
    else:
        last = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(n_valid, jnp.int32) - 1, 1, axis=1)
    logits = lm_head(cfg, params, last)
    return logits[:, 0], caches


def lm_paged_prefill(cfg, params, tokens, state, *, use_pallas: bool = False):
    """Prefill one request's (suffix) chunk straight into the paged pool
    (forward body shared with ``lm_paged_verify``).

    tokens [1, S] — S is a padded power-of-two bucket; state:
      * ``pages``      {"k","v"}: [L, P, ps, KV, hd] — global page pool
      * ``page_table`` [n] int32 — this request's page-table row
      * ``start``      traced scalar — tokens already cached (shared prefix
        pages + earlier chunks); the chunk holds positions start..start+S-1
      * ``n_valid``    traced scalar — real tokens in the chunk (the bucket
        tail past it is masked and written to the trash page)

    Returns (logits [1, V] of the last *valid* token, new_pages).  One
    compiled shape per bucket covers every (prompt_len, prefix_len, chunk)
    combination — the dispatch that used to jit per prompt length.
    ``use_pallas`` selects the scalar-prefetched Pallas chunked-prefill
    kernels (contiguous / ring / absorbed-MLA variants; HBM traffic ~
    pages actually held) over the traced whole-table gather.

    Dispatches on the family's page layout: per-head k/v pages (full
    attention's contiguous pages and swa/local's ring-wrapped window
    pages) vs MLA's latent ckv/krope pages.  Quantized (int8) pools add
    ``k_scale``/``v_scale`` leaves to each layer's kv dict — they ride the
    same ``lax.scan`` over layers with no structural change here; the
    attention layer quantizes on write and fuses dequant into the scores.
    """
    x, n_valid, new_pages = _paged_forward(cfg, params, tokens, state,
                                           use_pallas=use_pallas)
    last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    logits = lm_head(cfg, params, last)
    return logits[:, 0], new_pages


def lm_paged_verify(cfg, params, tokens, state, *, use_pallas: bool = False):
    """Speculative-decode verify forward: the drafted span runs through
    the same paged-prefill scatter (accepted tokens' K/V lands straight
    in the request's pages) but the head runs over *every* position —
    logits [S, V], one row per input token, row ``j`` predicting
    sequence index ``start + 1 + j``.  The engine replays its sampler
    over these rows to decide the accepted prefix; invalid tail rows
    (``j >= n_valid``) are masked into the trash page exactly like a
    bucketed prefill tail and their logits are simply ignored.
    ``use_pallas`` routes the drafted span's chunk-shaped attention
    through the same Pallas prefill kernels as ``lm_paged_prefill``."""
    x, _, new_pages = _paged_forward(cfg, params, tokens, state,
                                     use_pallas=use_pallas)
    logits = lm_head(cfg, params, x)
    return logits[0], new_pages


def _paged_forward(cfg, params, tokens, state, *, use_pallas: bool = False):
    """Shared paged prefill/verify body -> (x [1,S,d], n_valid, new_pages)."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    S = tokens.shape[1]
    start = jnp.asarray(state["start"], jnp.int32)
    n_valid = jnp.asarray(state["n_valid"], jnp.int32)
    positions = start + jnp.arange(S, dtype=jnp.int32)
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, layer_in):
        lp, kv = layer_in
        h = apply_norm(cfg, lp["ln1"], x)
        if cfg.attn_kind == "mla":
            a, new_kv = attn.paged_mla_prefill_apply(
                cfg, lp["attn"], h, positions, kv, state["page_table"],
                start, n_valid, use_pallas=use_pallas)
        else:
            a, new_kv = attn.paged_prefill_apply(
                cfg, lp["attn"], h, positions, kv, state["page_table"],
                start, n_valid, use_pallas=use_pallas)
        x = x + a
        h = apply_norm(cfg, lp["ln2"], x)
        if cfg.moe is not None:
            f, _ = moe_mod.moe_apply(cfg, lp["ff"], h, n_valid=n_valid,
                                     per_token=True)
        else:
            f = mlp_mod.mlp_apply(cfg, lp["ff"], h)
        x = x + f
        x = maybe_wsc(x, P(None, None, None))
        return x, new_kv

    x, new_pages = jax.lax.scan(body, x, (params["layers"], state["pages"]))
    x = apply_norm(cfg, params["final_norm"], x)
    return x, n_valid, new_pages


def lm_decode(cfg, params, tokens, caches):
    """One decode step. tokens [B,1]; caches stacked [L,...] trees.

    Returns (logits [B,V], new_caches)."""
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    index = caches["index"][0] if "index" in caches else caches["ckv_index"]
    positions = jnp.full((B, 1), 0, jnp.int32) + index
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, layer_in):
        lp, cache = layer_in
        x, new_cache, _ = decoder_layer_apply(cfg, lp, x, positions, cache=cache)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x)
    return logits[:, 0], new_caches


def paged_decoder_layer_apply(cfg, p, x, positions, kv, page_table, lengths,
                              use_pallas=False):
    """Decode-step layer over a shared paged KV pool.  Returns (x, new_kv).

    Dispatches on the family's page layout: per-head k/v pages for full and
    sliding-window/local attention (``paged_attention_apply``), latent
    ckv/krope pages for MLA (``paged_mla_attention_apply``)."""
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.attn_kind == "mla":
        a, new_kv = attn.paged_mla_attention_apply(
            cfg, p["attn"], h, positions, kv, page_table, lengths,
            use_pallas=use_pallas)
    else:
        a, new_kv = attn.paged_attention_apply(cfg, p["attn"], h, positions,
                                               kv, page_table, lengths,
                                               use_pallas=use_pallas)
    x = x + a
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        # per-token groups: concurrently decoding slots must never compete
        # for expert capacity (slot isolation == the vmapped slotted path)
        f, _ = moe_mod.moe_apply(cfg, p["ff"], h, per_token=True)
    else:
        f = mlp_mod.mlp_apply(cfg, p["ff"], h)
    x = x + f
    x = maybe_wsc(x, P(None, None, None))
    return x, new_kv


def lm_paged_decode(cfg, params, tokens, state, *, use_pallas: bool = False):
    """One decode step for *all* serving slots against a paged KV pool.

    Unlike ``lm_decode`` (one private ring cache per sequence, vmapped by
    the engine), the pool is shared, so the whole slot batch runs as one
    call.  tokens [slots, 1]; state:
      * ``pages``      {"k","v"}: [L, P, ps, KV, hd] — global page pool
      * ``page_table`` [slots, n] int32 — per-slot page ids (0 = trash)
      * ``pos``        [slots] int32 — tokens already cached per slot
        (= the position this step's token is written at)

    Returns (logits [slots, V], new_pages).  The page layout is the
    family's (``repro.serving.layouts``): contiguous k/v pages for full
    attention, ring-wrapped window pages for swa/local (the position
    mapping and window mask live in the paged-attention kernel/ref), and
    latent ckv/krope pages for MLA (absorbed decode).  Int8 pools carry
    ``k_scale``/``v_scale`` leaves per layer (quantize-on-append, dequant
    fused into the attention math) — transparent to the scan over layers.
    """
    params = cast_tree(params, cfg.compute_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    lengths = state["pos"]
    positions = lengths[:, None]
    x = embed_lookup(cfg, params, tokens, cd)

    def body(x, layer_in):
        lp, kv = layer_in
        x, new_kv = paged_decoder_layer_apply(
            cfg, lp, x, positions, kv, state["page_table"], lengths,
            use_pallas=use_pallas)
        return x, new_kv

    x, new_pages = jax.lax.scan(body, x, (params["layers"], state["pages"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head(cfg, params, x)
    return logits[:, 0], new_pages


def decode_cache_len(cfg, seq_len: int) -> int:
    """Ring-buffer length: bounded by the attention window when subquadratic."""
    if cfg.attn_kind in ("swa", "local") and cfg.window:
        return min(seq_len, cfg.window)
    return seq_len


def init_decode_caches(cfg, batch: int, seq_len: int):
    """Stacked [L,...] cache tree for lm_decode."""
    Lc = decode_cache_len(cfg, seq_len)
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.attn_kind == "mla":
        one = attn.mla_init_cache(batch, Lc, cfg, cd)
    else:
        one = attn.init_cache(batch, Lc, cfg.num_kv_heads,
                              cfg.resolved_head_dim, cd)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), one)


def decode_cache_specs(cfg, batch: int, seq_len: int):
    Lc = decode_cache_len(cfg, seq_len)
    cd = cfg.compute_dtype
    if cfg.attn_kind == "mla":
        one = attn.mla_cache_specs(batch, Lc, cfg, cd)
    else:
        one = attn.cache_specs(batch, Lc, cfg.num_kv_heads,
                               cfg.resolved_head_dim, cd)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape, s.dtype), one)
