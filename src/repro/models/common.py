"""Parameter-spec system + shared layer primitives.

Every model in ``repro.models`` is *functional*: it exposes

  ``param_specs(cfg) -> PyTree[ParamSpec]``
  ``apply(cfg, params, inputs, ...) -> outputs``

A ``ParamSpec`` carries shape, dtype, init distribution and *logical axis
names*.  Logical axes are mapped to mesh axes by sharding rules
(:func:`logical_to_mesh`), which is how one model definition serves:

  * smoke tests  (materialize small params on CPU),
  * the dry-run  (ShapeDtypeStructs, no allocation),
  * production   (NamedShardings for pjit / shard_map partial-auto).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

P = jax.sharding.PartitionSpec

# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------

INITS = ("normal", "scaled", "zeros", "ones", "embed", "small")


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names, len == len(shape)
    init: str = "scaled"                     # fan-in scaled normal
    dtype: str = "float32"
    fan_in_axis: int = -2                    # which axis is fan-in for "scaled"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        assert self.init in INITS, self.init


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_leaves(tree):
    return jax.tree.leaves(tree, is_leaf=is_spec)


def tree_map_specs(fn, tree, *rest):
    return jax.tree.map(fn, tree, *rest, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Materialization / shape-struct / sharding derivation
# ---------------------------------------------------------------------------

def _init_one(spec: ParamSpec, key) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * 0.02).astype(dtype)
    if spec.init == "small":
        return (jax.random.normal(key, spec.shape) * 1e-2).astype(dtype)
    if spec.init == "normal":
        return jax.random.normal(key, spec.shape).astype(dtype)
    # fan-in scaled
    fan_axis = spec.fan_in_axis if len(spec.shape) > 1 else 0
    fan_in = spec.shape[fan_axis]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * scale).astype(dtype)


def init_params(spec_tree, key):
    """Materialize a ParamSpec tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_shape_structs(spec_tree, shardings=None):
    """ShapeDtypeStruct tree for the dry-run (no allocation)."""
    if shardings is None:
        return tree_map_specs(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)), spec_tree)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype), sharding=sh),
        spec_tree, shardings, is_leaf=is_spec)


# Default logical-axis -> mesh-axis rules (Megatron-style TP on "model").
# "embed" maps to the FSDP axis in fsdp mode (see rules_for).
DEFAULT_RULES: Tuple[Tuple[str, Optional[str]], ...] = (
    ("vocab", "model"),
    ("heads", "model"),        # fused head*head_dim projections
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("expert", "model"),       # expert-parallel when divisible
    ("expert_mlp", None),      # per-arch override may set "model"
    ("lru", "model"),
    ("lru_in", None),          # input dim of square LRU gate matrices
    ("embed", None),
    ("embed2", None),          # second d_model-sized axis (e.g. wo output)
    ("layers", None),
    ("window", None),
    ("rank", None),            # low-rank/LoRA dims (MLA kv_lora, rwkv decay)
)


def rules_for(mesh_cfg, model_cfg=None) -> dict:
    """Resolve sharding rules for a (mesh, model) pair.

    fsdp mode shards the "embed" (d_model) weight dimension over the data axis
    — streaming ZeRO-3 via all_gather inside the layer scan.
    """
    rules = dict(DEFAULT_RULES)
    if mesh_cfg.dp_mode == "fsdp":
        # shard the d_model weight dim over EVERY dp axis (pod included)
        dp = tuple(a for a in mesh_cfg.axis_names if a in ("pod", "data"))
        rules["embed"] = dp if len(dp) > 1 else "data"
    for k, v in mesh_cfg.rules_override:
        rules[k] = v
    if model_cfg is not None and model_cfg.moe is not None:
        # expert-parallel only when the expert count divides the model axis
        msize = 1
        for s, a in zip(mesh_cfg.shape, mesh_cfg.axis_names):
            if a == "model":
                msize = s
        if model_cfg.moe.num_experts % max(msize, 1) != 0:
            rules["expert"] = None
            rules["expert_mlp"] = "model"
    return rules


def spec_to_pspec(spec: ParamSpec, rules: dict) -> P:
    """Logical axes -> PartitionSpec under the given rules."""
    return P(*(rules.get(a) if a is not None else None for a in spec.axes))


def logical_to_mesh(spec_tree, mesh, rules: dict):
    """ParamSpec tree -> NamedSharding tree."""
    def one(s: ParamSpec):
        return jax.sharding.NamedSharding(mesh, spec_to_pspec(s, rules))
    return tree_map_specs(one, spec_tree)


def manual_axis_specs(spec_tree, rules: dict, manual_axes: Tuple[str, ...]):
    """PartitionSpecs *restricted to manual axes* — what shard_map's in_specs
    needs for the params under partial-auto shard_map.  Auto-axis shardings
    flow through the jit-level NamedShardings instead."""
    def one(s: ParamSpec):
        out = []
        for a in s.axes:
            m = rules.get(a) if a is not None else None
            if isinstance(m, tuple):
                kept = tuple(x for x in m if x in manual_axes)
                out.append(kept if kept else None)
            else:
                out.append(m if m in manual_axes else None)
        return P(*out)
    return tree_map_specs(one, spec_tree)


def stack_specs(tree, n: int):
    """Prepend a scanned-layer dimension (logical axis "layers") to every leaf."""
    def one(s: ParamSpec):
        fan = s.fan_in_axis
        # keep fan-in pointing at the same physical axis after stacking
        if fan >= 0:
            fan += 1
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype, fan)
    return tree_map_specs(one, tree)


# ---------------------------------------------------------------------------
# Shared numerics
# ---------------------------------------------------------------------------

import contextlib

# When the whole mesh is auto (fsdp / serve paths), activation constraints
# must also pin the batch dim to the DP axes — otherwise P(None, ...) forces
# batch REPLICATION over data.  The trainer / launcher set this around
# tracing; inside manual shard_map regions it stays None (batch is manual).
_ACT_BATCH_AXES = None


@contextlib.contextmanager
def activation_batch_axes(axes):
    global _ACT_BATCH_AXES
    old = _ACT_BATCH_AXES
    _ACT_BATCH_AXES = tuple(axes) if axes else None
    try:
        yield
    finally:
        _ACT_BATCH_AXES = old


def maybe_wsc(x, spec: P):
    """with_sharding_constraint that no-ops outside a mesh context or when the
    referenced axes are absent/manual (smoke tests run on 1 CPU device)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        parts = list(spec)
        if (_ACT_BATCH_AXES and parts and parts[0] is None
                and x.ndim == len(parts) and x.shape[0] > 1):
            parts[0] = _ACT_BATCH_AXES
            spec = P(*parts)
        axes = set()
        for part in spec:
            if part is None:
                continue
            axes.update(part if isinstance(part, tuple) else (part,))
        for a in axes:
            if a not in mesh.axis_names:
                return x
            if mesh._name_to_type[a] != jax.sharding.AxisType.Auto:
                return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def cast_tree(tree, dtype):
    d = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(d) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_specs(cfg, shape_prefix=(), axes_prefix=()):
    """Norm params for one layer position (stacked under the layer scan)."""
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": ParamSpec(shape_prefix + (d,), axes_prefix + ("embed2",), init="ones"),
            "bias": ParamSpec(shape_prefix + (d,), axes_prefix + ("embed2",), init="zeros"),
        }
    return {"scale": ParamSpec(shape_prefix + (d,), axes_prefix + ("embed2",), init="zeros")}


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# --- RoPE ------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))           # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]                     # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation(name: str):
    return {
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "silu": jax.nn.silu,
        "swiglu": jax.nn.silu,   # gate activation used inside SwiGLU
        "geglu": jax.nn.gelu,
    }[name]


def dot(x, w, compute_dtype=None):
    """Linear apply with dtype management (bf16 compute, fp32 master)."""
    cd = compute_dtype or x.dtype
    return jax.lax.dot_general(
        x.astype(cd), w.astype(cd),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32 if cd == jnp.bfloat16 else None,
    ).astype(cd)
