"""Mixture-of-Experts substrate (token-choice top-k, GShard-style dispatch).

TPU adaptation: dispatch/combine are *dense grouped einsums* with a per-group
capacity limit — no dynamic shapes, MXU-friendly, and the expert dimension of
the dispatch buffer is pinned to the "model" mesh axis so GSPMD emits the
expert-parallel all-to-all exactly where MPI_Alltoall would sit in an MPI
implementation (paper §II-B maps collectives, not point-to-point, onto scale).

Expert placement (see common.rules_for):
  * num_experts % model_axis == 0  -> expert-parallel ("expert" -> "model")
  * otherwise                      -> per-expert tensor-parallel
    ("expert_mlp" -> "model"), e.g. Mixtral's 8 experts on a 16-way axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, activation, dot, maybe_wsc

P = jax.sharding.PartitionSpec


def moe_specs(cfg):
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    specs = {
        "router": ParamSpec((d, m.num_experts), ("embed", "expert"), init="small"),
        "w_gate": ParamSpec((m.num_experts, d, f), ("expert", "embed", "expert_mlp")),
        "w_up": ParamSpec((m.num_experts, d, f), ("expert", "embed", "expert_mlp")),
        "w_down": ParamSpec((m.num_experts, f, d), ("expert", "expert_mlp", "embed2")),
    }
    if m.num_shared_experts:
        fs = m.d_ff_shared or f * m.num_shared_experts
        specs["shared"] = {
            "w_gate": ParamSpec((d, fs), ("embed", "mlp")),
            "w_up": ParamSpec((d, fs), ("embed", "mlp")),
            "w_down": ParamSpec((fs, d), ("mlp", "embed2")),
        }
    return specs


def _choose_group_size(n_tokens: int, num_experts: int) -> int:
    """Pick a dispatch group size keeping the [g,E,C] combine tensor modest."""
    for g in (4096, 2048, 1024, 512, 256, 128):
        if n_tokens % g == 0 and g * num_experts <= 4096 * 16:
            return g
    return n_tokens


def moe_apply(cfg, p, x, *, n_valid=None,
              per_token: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (y [B,S,D], aux_loss scalar fp32).

    ``n_valid`` (traced, serving prefill): positions >= n_valid along S are
    a masked bucket tail — their router assignments are zeroed *before*
    capacity accounting, so padding can never displace a real token from
    an expert (their own outputs are garbage either way; the engine masks
    them downstream).  ``per_token`` (serving paths): dispatch in groups of
    one token — C = 1 then admits every token's full top-k, so routing is
    *drop-free* and strictly per-token.  Training keeps GShard capacity
    semantics; serving uses per_token everywhere because capacity
    truncation couples tokens across group shapes (bucket widths, chunk
    boundaries, concurrently decoding slots, prefix-cache-skipped
    prefixes), which would break the engine's token-identity guarantee —
    warm != cold, paged != slotted — whenever a drop binds.  The price is
    the dense dispatch running E instead of ~K·cf expert rows per token at
    serve time; a ragged grouped-GEMM serve kernel is the ROADMAP answer.
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    cd = x.dtype
    N = B * S
    g = 1 if per_token else _choose_group_size(N, E)
    G = N // g
    C = max(int(g * K / E * m.capacity_factor), 1)
    C = min(C, g)

    xf = x.reshape(G, g, D)
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))           # [G,g,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, K)                        # [G,g,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)           # renormalize

    # --- capacity assignment (choice-major priority, GShard) ---------------
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.int32)                # [G,g,K,E]
    if n_valid is not None:
        # bucket-tail padding routes nowhere: it must not consume expert
        # capacity (a padding row displacing a real token would make the
        # compiled bucket width leak into valid tokens' outputs)
        vmask = jnp.broadcast_to(
            (jnp.arange(S) < n_valid)[None, :], (B, S)).reshape(G, g)
        onehot = onehot * vmask[..., None, None].astype(onehot.dtype)
    prio = onehot.transpose(0, 2, 1, 3).reshape(G, K * g, E)        # choice-major
    pos = jnp.cumsum(prio, axis=1) * prio - 1                       # position in expert
    pos = pos.reshape(G, K, g, E).transpose(0, 2, 1, 3)             # [G,g,K,E]
    keep = (pos >= 0) & (pos < C)
    pos = jnp.where(keep, pos, 0)
    slot_onehot = jax.nn.one_hot(pos, C, dtype=cd) * keep[..., None].astype(cd)
    # combine[g,t,E,C] = Σ_k gate * slot
    combine = jnp.einsum("gtke,gtkec->gtec",
                         (gate_vals[..., None] * onehot.astype(jnp.float32)).astype(cd),
                         slot_onehot)                               # [G,g,E,C]
    dispatch = (combine > 0).astype(cd)

    # --- dispatch -> expert FFN -> combine ---------------------------------
    xe = jnp.einsum("gtd,gtec->gecd", xf, dispatch)                 # [G,E,C,D]
    xe = maybe_wsc(xe, P(None, "model", None, None))                # pin EP all-to-all
    act = activation(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(cd))) * \
        jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(cd))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cd))    # [G,E,C,D]
    y = jnp.einsum("gecd,gtec->gtd", ye, combine)                   # [G,g,D]
    y = y.reshape(B, S, D)

    if m.num_shared_experts:
        sh = p["shared"]
        hs = act(dot(x, sh["w_gate"], cd)) * dot(x, sh["w_up"], cd)
        y = y + dot(hs, sh["w_down"], cd)

    # --- Switch load-balance auxiliary loss --------------------------------
    frac = jnp.mean(onehot.astype(jnp.float32), axis=(1, 2))        # [G,E] token fraction·K
    pmean = jnp.mean(probs, axis=1)                                 # [G,E]
    aux = E * jnp.mean(jnp.sum(frac * pmean, axis=-1)) / K
    return y, aux.astype(jnp.float32)
