"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "mixtral-8x22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=32_768,
        attn_kind="swa", window=4096, act="swiglu", subquadratic=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="swa", window=8, act="swiglu", subquadratic=True,
        remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    )
