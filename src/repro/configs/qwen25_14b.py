"""qwen2.5-14b [dense] — GQA with QKV bias.  [hf:Qwen/Qwen2.5; hf]
48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=13824, vocab_size=152_064,
        attn_kind="full", qkv_bias=True, act="swiglu", rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="full", qkv_bias=True, act="swiglu", remat="none",
    )
