"""AlexNet — the paper's own primary benchmark network (Table II).

Not part of the assigned 10-arch pool; included because the paper's
evaluation (Figs. 4-8) centres on it.  family="cnn" is handled by the
benchmark/equivalence harness rather than the LM registry.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "alexnet"


def config() -> ModelConfig:
    # CNN configs reuse ModelConfig loosely: d_model == input resolution,
    # vocab_size == classes.  See repro.models.cnn for the real structure.
    return ModelConfig(
        name=ARCH_ID, family="cnn",
        num_layers=8, d_model=224, num_heads=1, num_kv_heads=1,
        d_ff=4096, vocab_size=1000, attn_kind="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinycnn", family="cnn",
        num_layers=4, d_model=16, num_heads=1, num_kv_heads=1,
        d_ff=64, vocab_size=10, attn_kind="full",
    )
