"""minitron-8b [dense] — pruned nemotron (squared-ReLU-family MLP -> relu).
[arXiv:2407.14679; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "minitron-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=256_000,
        attn_kind="full", act="relu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="full", act="relu", remat="none",
    )
