"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

ARCH_ID = "recurrentgemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256_000,
        attn_kind="local", window=2048, act="geglu",
        tie_embeddings=True, scale_embed=True, subquadratic=True,
        rglru=RGLRUConfig(lru_width=2560, conv1d_width=4,
                          attention_window=2048),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="local", window=8, act="geglu",
        tie_embeddings=True, scale_embed=True, subquadratic=True, remat="none",
        rglru=RGLRUConfig(lru_width=64, conv1d_width=4, attention_window=8),
    )
