"""whisper-tiny [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings).  [arXiv:2212.04356]
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
"""
from repro.configs.base import EncDecConfig, ModelConfig

ARCH_ID = "whisper-tiny"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
        head_dim=64, d_ff=1536, vocab_size=51_865,
        attn_kind="full", qkv_bias=True, act="gelu", norm="layernorm",
        tie_embeddings=True,
        encdec=EncDecConfig(num_encoder_layers=4, encoder_seq_len=1500),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="full", qkv_bias=True, act="gelu", norm="layernorm",
        tie_embeddings=True, remat="none",
        encdec=EncDecConfig(num_encoder_layers=2, encoder_seq_len=24),
    )
