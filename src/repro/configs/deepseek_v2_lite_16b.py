"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 64-expert top-6 MoE with
2 shared experts.  [arXiv:2405.04434; hf]
27L d_model=2048 16H d_ff_expert=1408 vocab=102400.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-lite-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=192, d_ff=1408, vocab_size=102_400,
        attn_kind="mla", act="swiglu",
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared_experts=2, d_ff_shared=2816),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=24, d_ff=64, vocab_size=256,
        attn_kind="mla", act="swiglu", remat="none",
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      num_shared_experts=1, d_ff_shared=64),
    )
