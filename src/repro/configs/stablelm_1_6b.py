"""stablelm-1.6b [dense] — MHA (kv == heads), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b; unverified]
24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "stablelm-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=5632, vocab_size=100_352,
        attn_kind="full", act="swiglu", norm="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="full", act="swiglu", norm="layernorm", remat="none",
    )
