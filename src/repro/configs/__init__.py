"""Architecture configs: the 10 assigned archs + the paper's AlexNet."""
from repro.configs import (alexnet, deepseek_v2_lite_16b, minitron_8b,
                           mistral_nemo_12b, mixtral_8x22b, pixtral_12b,
                           qwen25_14b, recurrentgemma_2b, rwkv6_1_6b,
                           stablelm_1_6b, whisper_tiny)
from repro.configs.base import (MeshConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ServeConfig, ShapeConfig,
                                MULTI_POD, SINGLE_POD)
from repro.configs.shapes import SHAPES, get_shape

_MODULES = (recurrentgemma_2b, qwen25_14b, stablelm_1_6b, minitron_8b,
            mistral_nemo_12b, deepseek_v2_lite_16b, mixtral_8x22b,
            whisper_tiny, rwkv6_1_6b, pixtral_12b)

ARCHS = {m.ARCH_ID: m for m in _MODULES}
ALL_ARCHS = tuple(ARCHS)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch in (alexnet.ARCH_ID, "tinycnn"):
        return alexnet.smoke_config() if (smoke or arch == "tinycnn") \
            else alexnet.config()
    if arch == "examples-lm-100m":
        # ~120M-param dense LM for the end-to-end example driver
        return ModelConfig(
            name=arch, family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32_000, attn_kind="full", act="swiglu",
            compute_dtype="float32", remat="none")
    try:
        mod = ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}") from None
    if smoke:
        # smoke tests execute on CPU: fp32 avoids XLA:CPU's missing
        # bf16xbf16->f32 dot thunk (full configs keep bf16 — TPU target).
        return mod.smoke_config().replace(compute_dtype="float32")
    return mod.config()
