"""Configuration dataclasses for MaTEx-JAX.

Every run is described by a ``RunConfig`` = (ModelConfig, ShapeConfig,
MeshConfig).  Model configs for the ten assigned architectures live in
``repro.configs.<arch>``; shape presets in ``repro.configs.shapes``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (token-choice top-k routing)."""
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router_jitter: float = 0.0
    # load-balancing auxiliary loss weight (Switch-style)
    aux_loss_weight: float = 0.01
    # capacity factor for dense one-hot dispatch (einsum-based, TPU friendly)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention configuration."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrence configuration."""
    lru_width: int = 0            # 0 => same as d_model
    conv1d_width: int = 4
    # pattern: how many recurrent blocks per attention block (2 recurrent : 1 local attn)
    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    attention_window: int = 2048


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 (Finch) configuration."""
    head_size: int = 64
    decay_lora: int = 64          # rank of data-dependent decay LoRA
    token_shift: bool = True


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (whisper-style) extras; frontend is a stub."""
    num_encoder_layers: int = 4
    encoder_seq_len: int = 1500   # whisper frame count after conv frontend
    frontend: str = "stub"        # precomputed frame embeddings via input_specs()


@dataclass(frozen=True)
class VLMConfig:
    """Vision-language (pixtral-style) extras; vision tower is a stub."""
    num_image_tokens: int = 1024  # precomputed patch embeddings per image
    frontend: str = "stub"


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

ATTN_KINDS = ("full", "swa", "local", "mla", "none")
FAMILIES = ("dense", "moe", "hybrid", "ssm", "encdec", "vlm", "cnn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    attn_kind: str = "full"       # one of ATTN_KINDS
    window: int = 0               # sliding/local attention window (0 = n/a)
    qkv_bias: bool = False
    act: str = "swiglu"           # "swiglu" | "gelu" | "geglu" | "relu"
    norm: str = "rmsnorm"         # "rmsnorm" | "layernorm"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    scale_embed: bool = False     # gemma-style sqrt(d_model) embedding scaling
    norm_eps: float = 1e-6
    # sub-configs (None when not applicable)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # numerics
    param_dtype: str = "float32"  # master weights
    compute_dtype: str = "bfloat16"
    # remat ("none" | "full" | "dots" — checkpoint-dots policy)
    remat: str = "full"
    # traced-attention tile sizes (perf knobs; kernels have their own)
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    # flash-style attention backward: nested remat recomputes the score
    # blocks instead of storing them (kills the dominant HBM term of the
    # traced path; see EXPERIMENTS.md §Perf).  Off by default so the
    # baseline table stays paper-faithful; hillclimb flips it.
    attn_remat: bool = False
    # set True for architectures whose attention is subquadratic / bounded-state,
    # which qualifies them for the long_500k cell.
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def validate(self) -> None:
        assert self.family in FAMILIES, self.family
        assert self.attn_kind in ATTN_KINDS, self.attn_kind
        if self.family == "moe":
            assert self.moe is not None
        if self.attn_kind == "mla":
            assert self.mla is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. embeddings)."""
        from repro.models.registry import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# ShapeConfig — the four assigned input-shape presets
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                     # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    def validate(self) -> None:
        assert self.kind in ("train", "prefill", "decode")


# ---------------------------------------------------------------------------
# MeshConfig + distribution options (the paper's feature knobs)
# ---------------------------------------------------------------------------

ALLREDUCE_STRATEGIES = (
    "fused",          # single flat-bucket psum
    "layerwise",      # paper §III-D.2: ordered, per-layer reduction
    "bucketed",       # size-capped buckets (overlap-friendly)
    "hierarchical",   # intra-pod then inter-pod (topology-aware)
    "reduce_scatter", # beyond-paper ZeRO-1: RS + optimizer + AG
    "compressed",     # beyond-paper: bf16 wire format + fp32 error feedback
)

DP_MODES = ("replicated", "fsdp")


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    # paper-faithful vs beyond-paper parameter placement
    dp_mode: str = "replicated"
    allreduce: str = "layerwise"
    bucket_bytes: int = 32 * 1024 * 1024   # for "bucketed"
    # sharding rule overrides: logical axis -> mesh axis (or None)
    rules_override: Tuple[Tuple[str, Optional[str]], ...] = ()

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def model_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a == "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def validate(self) -> None:
        assert len(self.shape) == len(self.axis_names)
        assert self.dp_mode in DP_MODES
        assert self.allreduce in ALLREDUCE_STRATEGIES


SINGLE_POD = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axis_names=("pod", "data", "model"))


# ---------------------------------------------------------------------------
# ServeConfig — continuous-batching inference engine (repro.serving)
# ---------------------------------------------------------------------------

SERVE_POLICIES = ("fcfs", "priority")
KV_LAYOUTS = ("auto", "paged", "slotted")

# KV page storage dtypes ("fp32" = the family's native compute dtype; "int8"
# = quantized pages + per-row scale leaves).  Mirrors
# repro.serving.layouts.KV_DTYPES — kept literal here so ServeConfig
# construction never imports the serving layer.
KV_DTYPES = ("fp32", "int8")


def floor_pow2(n: int) -> int:
    """Largest power of two <= n (n >= 1).  The auto-sizing rule every
    page-size default goes through, so it always satisfies the
    ``enable_prefix_cache`` power-of-two validation below."""
    assert n >= 1, n
    return 1 << (n.bit_length() - 1)


@dataclass(frozen=True)
class ServeConfig:
    """Knobs for the continuous-batching serving engine.

    The decode batch shape is fixed at ``max_batch`` slots so XLA compiles
    the batched decode exactly once; requests are inserted into / evicted
    from KV-cache slots individually (no batch re-prefill).

    KV memory is page-granular for every family with a ``KVLayout``
    (``kv_layout="auto"`` picks paged when the bundle declares one): per-
    head k/v pages for full attention, ring-wrapped window pages for
    sliding-window/local attention (a page must fit and tile the window —
    see ``check_window``), latent ckv/krope pages for MLA.  Pages of
    ``page_size`` tokens are allocated lazily as a request's position
    grows and returned on eviction, so cache bytes held track actual
    sequence lengths instead of ``max_batch x max_seq_len``.  Recurrent
    families (RG-LRU / RWKV: O(1) state per slot — nothing to page) stay
    on the slotted pool.  ``num_pages`` provisions the shared pool
    (0 = worst case ``max_batch * ceil(max_seq_len / page_size)`` + the
    reserved trash page; windowed layouts cap the per-slot worst case at
    ``window // page_size``); under-provisioning oversubscribes memory —
    the engine preempts the youngest request on page pressure.

    Prefill-path knobs (engine-level optimization pass, see
    ``serving/engine.py``):

    * ``enable_prefix_cache`` — paged layout only: requests whose prompt
      shares a page-aligned prefix with previously served prompts map the
      cached pages read-only (copy-on-write on a partially reused last
      page) and prefill just the uncached suffix.  Requires a power-of-two
      ``page_size`` (block hashing chunks prompts at page granularity).
    * ``prefill_bucket`` — pad prefill lengths to powers of two with masked
      tails so the per-prompt-length jit cache stays O(log max_seq_len)
      instead of one XLA entry per distinct ``(prompt_len, cache_len)``.
    * ``prefill_chunk_tokens`` — split prefills longer than this into
      chunks run one per engine cycle, interleaved with decode steps, so a
      long prompt no longer stalls running streams' inter-token latency
      (0 = never split).  Paged layout only; the slotted path keeps
      bucketing but prefills whole prompts.
    * ``max_prefills_per_step`` — admission bound: how many *requests* may
      start prefilling per engine cycle (formerly ``prefill_chunk``, which
      remains as a deprecated constructor alias).
    Speculative decoding (``serving/spec.py``; paged layout only):

    * ``enable_spec`` — let the engine draft continuation tokens from each
      request's own history (n-gram prompt lookup) and verify them in one
      paged forward per slot.  Verification replays the engine's own
      sampler at every drafted position, so output is token-identical to
      ``enable_spec=False`` for greedy and sampled requests alike — the
      knob only trades host drafting + one verify forward against the
      decode steps the accepted tokens would have cost.
    * ``spec_tokens`` — maximum draft tokens proposed (and verified) per
      slot per cycle.

    * ``pipeline_depth`` — engine submit/retire pipelining: 2 (default)
      overlaps the next cycle's host planning against the in-flight device
      step (plan N+1 and submit it while N's results are still
      materializing, retire N afterwards); 1 is the synchronous escape
      hatch (every cycle retires before the next plans — what
      ``launch/serve.py --sync`` sets).  Greedy output is token-identical
      either way; depth changes scheduling latency only.

    Observability (``repro.obs``):

    * ``trace`` — record per-phase engine spans, per-request lifecycle
      spans and page-pool cache events into a bounded in-memory ring
      (exportable as a Perfetto-loadable Chrome trace; per-phase seconds
      fold into ``ServingMetrics.summary()``).  Traced mode fences device
      calls with ``block_until_ready`` so host and device time separate —
      that sync costs throughput, so leave it off for measured perf runs.
    * ``trace_capacity`` — ring-buffer bound (events); oldest drop first.
    """
    max_batch: int = 8            # decode slots (fixed batched-decode shape)
    max_queue: int = 64           # admission control: reject beyond this
    max_seq_len: int = 256        # per-slot KV-cache capacity (prompt + new)
    max_new_tokens: int = 32      # default generation budget per request
    policy: str = "fcfs"          # "fcfs" | "priority" (priority can preempt)
    # request admissions per engine cycle (None = default 2; the sentinel
    # lets the deprecated alias detect an explicitly-passed value even when
    # it equals the default)
    max_prefills_per_step: Optional[int] = None
    decode_steps: int = 4         # decode steps per cycle between admissions
    pipeline_depth: int = 2       # 2 = async submit/retire overlap, 1 = sync
    eos_token: int = -1           # stop token (-1 disables early stop)
    kv_layout: str = "auto"       # "auto" | "paged" | "slotted"
    # KV page storage dtype: "fp32" keeps the family's native compute dtype;
    # "int8" stores k/v pages quantized (symmetric per-(page, offset,
    # kv-head) fp32 scales as extra pool leaves, dequant fused into the
    # paged-attention kernels).  Paged per-head layouts only — rejected for
    # MLA (latent rank is contracted) and slotted-only families by
    # check_kv_dtype once the engine knows the layout.
    kv_dtype: str = "fp32"        # "fp32" | "int8" (paged k/v pages only)
    page_size: int = 16           # tokens per KV page (paged layout)
    num_pages: int = 0            # shared page pool size (0 = worst case)
    spec_tokens: int = 4          # max draft tokens per slot per cycle
    enable_spec: bool = True      # n-gram speculative decoding (paged)
    enable_prefix_cache: bool = True   # share prompt-prefix pages (paged)
    prefill_bucket: bool = True        # power-of-two prefill length buckets
    prefill_chunk_tokens: int = 0      # chunked prefill size (0 = whole)
    trace: bool = False                # repro.obs engine tracing (fenced)
    trace_capacity: int = 1 << 16      # trace ring-buffer bound (events)
    # Pallas paged-attention kernels (decode + chunked prefill + verify).
    # None = auto (kernels on TPU, jnp gather path elsewhere); True forces
    # the kernels everywhere (interpret mode off-TPU — slow but correct,
    # what the CI smoke job and the kernel-identity tests run); False
    # forces the jnp gather path even on TPU.
    use_pallas: Optional[bool] = None
    # deprecated alias for max_prefills_per_step (folded in __post_init__)
    prefill_chunk: Optional[int] = None

    _INT_KNOBS = ("max_batch", "max_queue", "max_seq_len", "max_new_tokens",
                  "max_prefills_per_step", "decode_steps", "pipeline_depth",
                  "num_pages", "page_size", "prefill_chunk_tokens",
                  "spec_tokens", "trace_capacity")

    def __post_init__(self):
        # normalize numpy integer knobs (e.g. max_batch=arr.shape[0]) so
        # equality/hashing used by engine caches sees plain ints
        import numbers
        if self.prefill_chunk is not None:
            import warnings
            if self.max_prefills_per_step is not None \
                    and self.max_prefills_per_step != self.prefill_chunk:
                raise ValueError(
                    f"conflicting knobs: max_prefills_per_step="
                    f"{self.max_prefills_per_step} and its deprecated alias "
                    f"prefill_chunk={self.prefill_chunk} — pass only "
                    "max_prefills_per_step")
            warnings.warn(
                "ServeConfig.prefill_chunk is deprecated; it bounds request "
                "admissions per cycle and is now max_prefills_per_step "
                "(prefill_chunk_tokens is the *token* chunking knob)",
                DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "max_prefills_per_step",
                               self.prefill_chunk)
            object.__setattr__(self, "prefill_chunk", None)
        if self.max_prefills_per_step is None:
            object.__setattr__(self, "max_prefills_per_step", 2)
        for knob in self._INT_KNOBS:
            v = getattr(self, knob)
            if isinstance(v, numbers.Integral) and not isinstance(v, int):
                object.__setattr__(self, knob, int(v))
        # fail at construction, not deep inside PagedKVCachePool / the
        # engine loop: every ServeConfig in the system is valid by existence
        self.validate()

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_seq_len // self.page_size)

    def validate(self) -> None:
        if self.policy not in SERVE_POLICIES:
            raise ValueError(
                f"policy={self.policy!r} not in {SERVE_POLICIES}")
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(
                f"kv_layout={self.kv_layout!r} not in {KV_LAYOUTS}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype={self.kv_dtype!r} not in {KV_DTYPES}")
        if self.kv_dtype != "fp32" and self.kv_layout == "slotted":
            raise ValueError(
                f"kv_dtype={self.kv_dtype!r} requires a paged layout "
                f"(kv_layout='paged' or 'auto'), got kv_layout='slotted': "
                "the slotted pool stores the bundle's native decode state "
                "and never quantizes")
        for knob, least in (("max_batch", 1), ("max_queue", 1),
                            ("max_seq_len", 2), ("max_new_tokens", 1),
                            ("max_prefills_per_step", 1), ("decode_steps", 1),
                            ("page_size", 1), ("num_pages", 0),
                            ("prefill_chunk_tokens", 0), ("spec_tokens", 1),
                            ("trace_capacity", 1)):
            v = getattr(self, knob)
            if not isinstance(v, int) or isinstance(v, bool) or v < least:
                raise ValueError(f"{knob}={v!r} must be an int >= {least}")
        # depths beyond 2 would need per-depth retire queues and buy nothing:
        # one in-flight device step already hides the host plan under it
        if self.pipeline_depth not in (1, 2):
            raise ValueError(
                f"pipeline_depth={self.pipeline_depth!r} must be 1 "
                "(synchronous submit/retire) or 2 (plan the next cycle "
                "while one device step is in flight)")
        for knob in ("enable_prefix_cache", "enable_spec", "prefill_bucket",
                     "trace"):
            if not isinstance(getattr(self, knob), bool):
                raise ValueError(f"{knob}={getattr(self, knob)!r} must be "
                                 "a bool")
        if self.use_pallas is not None \
                and not isinstance(self.use_pallas, bool):
            raise ValueError(
                f"use_pallas={self.use_pallas!r} must be None (auto: "
                "kernels on TPU only) or a bool")
        # slotted never pages, so page_size is inert there; "auto" may
        # resolve to paged, so it must satisfy the block-hashing constraint
        if self.enable_prefix_cache and self.kv_layout != "slotted" \
                and self.page_size & (self.page_size - 1):
            raise ValueError(
                f"page_size={self.page_size} must be a power of two when "
                "enable_prefix_cache=True (prefix blocks are hashed at page "
                "granularity)")
        # (max_new_tokens is only the *default* per-request budget; the
        # engine checks prompt+max_new <= max_seq_len per submit, so it may
        # legitimately exceed max_seq_len here)
        if self.page_size > self.max_seq_len:
            raise ValueError(
                f"page_size={self.page_size} exceeds max_seq_len="
                f"{self.max_seq_len}: a single page would never fill — "
                "shrink page_size (the pool rounds capacity up to pages)")
        # a pool smaller than one slot's worth (+ trash page) deadlocks the
        # engine: a lone max-length request could never be placed
        if self.num_pages and self.num_pages < self.pages_per_slot + 1:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold one max_seq_len "
                f"request (needs >= {self.pages_per_slot + 1} pages: "
                f"{self.pages_per_slot} per slot + the reserved trash page)")

    def check_window(self, window: int) -> None:
        """Model-aware validation for windowed-attention families (the
        engine calls this once it knows the family's ``KVLayout``): ring-
        wrapped window pages must *tile* the window.  Delegates to the
        layout seam's single implementation (imported at call time —
        ``repro.serving`` sits above this module)."""
        if self.kv_layout == "slotted":
            return
        from repro.serving.layouts import check_window_page_size
        check_window_page_size(self.page_size, window)

    def check_kv_dtype(self, layout) -> None:
        """Model-aware validation for quantized KV (the engine calls this
        once it knows the family's ``KVLayout``, matching ``check_window``):
        ``kv_dtype="int8"`` needs a per-head paged layout — MLA latent
        pages and slotted-only families are rejected with an error naming
        both knobs.  Delegates to the layout seam's single implementation
        (imported at call time — ``repro.serving`` sits above this
        module).  ``layout`` is the family's base ``KVLayout`` or None when
        the engine resolved to the slotted pool."""
        from repro.serving.layouts import check_kv_dtype_layout
        check_kv_dtype_layout(self.kv_dtype, layout)

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# RunConfig
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adam"            # "sgd" | "momentum" | "adagrad" | "adam" | "adamw"
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0        # 0 disables


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = field(default_factory=lambda: SINGLE_POD)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0
    microbatch: int = 0           # 0 => no gradient accumulation

    def validate(self) -> None:
        self.model.validate()
        self.shape.validate()
        self.mesh.validate()
