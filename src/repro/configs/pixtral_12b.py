"""pixtral-12b [vlm] — mistral-nemo-12b backbone + stub pixtral-ViT frontend
(input_specs provides precomputed patch embeddings).
[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
"""
from repro.configs.base import ModelConfig, VLMConfig

ARCH_ID = "pixtral-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131_072,
        attn_kind="full", act="swiglu", rope_theta=1e6,
        vlm=VLMConfig(num_image_tokens=1024),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="full", act="swiglu", remat="none",
        vlm=VLMConfig(num_image_tokens=8),
    )
