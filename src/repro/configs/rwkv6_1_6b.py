"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]
24L d_model=2048 d_ff=7168 vocab=65536.
"""
from repro.configs.base import ModelConfig, RWKVConfig

ARCH_ID = "rwkv6-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=7168, vocab_size=65_536,
        attn_kind="none", act="relu", norm="layernorm", subquadratic=True,
        rwkv=RWKVConfig(head_size=64, decay_lora=64),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="none", act="relu", norm="layernorm", subquadratic=True,
        remat="none",
        rwkv=RWKVConfig(head_size=16, decay_lora=8),
    )
