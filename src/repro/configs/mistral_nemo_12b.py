"""mistral-nemo-12b [dense] — 128k-context full attention.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "mistral-nemo-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131_072,
        attn_kind="full", act="swiglu", rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        attn_kind="full", act="swiglu", remat="none",
    )
