"""MaTEx-JAX: user-transparent distributed training and serving.

User scripts go through ``repro.api`` (``api.load(arch) -> Session``);
everything else is runtime the Session owns.
"""
import jax as _jax

# Old jax (no native shard_map) also predates the sharding-invariant
# threefry default; without it, parameter init *values* change with the
# param sharding (fsdp vs replicated), breaking the transparency guarantee
# that distribution is invisible to numerics.  Align with new-jax defaults.
if not hasattr(_jax, "shard_map"):
    _jax.config.update("jax_threefry_partitionable", True)
