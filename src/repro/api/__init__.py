"""``repro.api`` — the user-transparent facade (the only supported
entrypoint for user scripts).

    from repro import api

    session = api.load("qwen2.5-14b", smoke=True, mesh="2x2")
    session.train(steps=100)                       # transparent DP training
    session.generate([3, 1, 4, 1, 5], max_new=16)  # continuous-batch decode

The script stays sequential; the Session owns meshes, shardings, configs,
registry bundles, trainers, engines and checkpoints — distribution is
selected by the ``mesh=`` config alone, per the paper's thesis.
"""
from repro.api.session import (CapabilityError, Session, TrainResult, load,
                               parse_mesh)

__all__ = ["CapabilityError", "Session", "TrainResult", "load", "parse_mesh"]
