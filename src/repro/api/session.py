"""The user-transparent Session: one object, three workloads.

The paper's thesis (MaTEx-TensorFlow §III) is that the *user script* stays
sequential and the *runtime* owns distribution.  ``repro.api`` is where that
thesis meets the repo's surface area: ``load(arch)`` returns a ``Session``
that owns the mesh/sharding lifecycle, the resolved configs and the registry
bundle, and exposes

  * ``session.train(steps=...)``      — TransparentTrainer + sharded data +
                                        checkpoint / elastic restore,
  * ``session.serve(requests)``       — the continuous-batching engine
                                        (paged or slotted KV, chosen by the
                                        bundle's declared capabilities),
  * ``session.generate(prompt(s))``   — one-shot greedy generation over the
                                        same engine.

Distribution is config, not code: ``load(arch, mesh="4x2")`` runs the same
script data-parallel x tensor-parallel; ``load(arch)`` runs it on one
device.  Capability errors surface at load time (``require=("serve",)``) or
as a one-line ``CapabilityError`` on first use — never as an ``is None``
crash mid-run.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.configs import get_config
from repro.configs.base import (MeshConfig, ModelConfig, OptimizerConfig,
                                RunConfig, ServeConfig, ShapeConfig)
from repro.models import registry

MeshLike = Union[None, str, Tuple[int, ...], MeshConfig]

#: axis names by mesh rank: "4" -> 4x1 data x model, "4x2" -> data x model,
#: "2x4x2" -> pod x data x model (the paper's two-pod layout)
_AXES_BY_RANK = {2: ("data", "model"), 3: ("pod", "data", "model")}

#: auto-sized serve capacity rounds up to this bucket (bounds engine-cache
#: cardinality under varying prompt lengths)
_SEQ_BUCKET = 64

#: engines kept per Session (oldest evicted; each holds a full KV pool)
_MAX_ENGINES = 4


class CapabilityError(ValueError):
    """A workload the loaded family does not declare (see
    ``ModelBundle.capabilities``)."""


def parse_mesh(spec: MeshLike) -> Optional[MeshConfig]:
    """``"2x2"`` / ``(2, 2)`` / ``MeshConfig`` / ``None`` -> ``MeshConfig``.

    Strings are ``D``, ``DxM`` or ``PxDxM`` (data / model / pod extents);
    this is the single parser behind ``--mesh`` in every launch driver and
    the ``mesh=`` argument of ``repro.api.load``.
    """
    if spec is None or spec == "":
        return None
    if isinstance(spec, MeshConfig):
        spec.validate()
        return spec
    if isinstance(spec, str):
        try:
            shape = tuple(int(x) for x in spec.lower().split("x"))
        except ValueError:
            raise ValueError(
                f"mesh spec {spec!r} is not of the form 'D', 'DxM' or "
                "'PxDxM' (e.g. '2x2' = 2-way data x 2-way model)") from None
    else:
        shape = tuple(int(x) for x in spec)
    if len(shape) == 1:
        # pure DP (the paper's setting): normalize to a 2-D mesh with a
        # size-1 model axis — the sharding rules always name "model"
        shape = shape + (1,)
    axes = _AXES_BY_RANK.get(len(shape))
    if axes is None or any(s < 1 for s in shape):
        raise ValueError(
            f"mesh shape {shape} must be 1-3 positive extents "
            "(data | data x model | pod x data x model)")
    return MeshConfig(shape=shape, axis_names=axes)


@dataclass
class TrainResult:
    """What ``Session.train`` hands back: the per-step loss trajectory plus
    the last step's metrics and the straggler-monitor summary."""
    losses: List[float]
    metrics: Dict[str, float]
    step: int
    elapsed_s: float
    straggler: Dict[str, float] = field(default_factory=dict)

    @property
    def loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


class Session:
    """One architecture bound to one (optional) mesh; owns params, trainer
    and engine lifecycles so user scripts never touch them directly.

    Construct through :func:`load`.  The Session is lazy: nothing touches
    jax device state until the first ``train`` / ``serve`` / ``generate``
    call, so sessions can be created before a driver decides device counts.
    """

    def __init__(self, model_cfg: ModelConfig,
                 mesh_cfg: Optional[MeshConfig] = None, *, seed: int = 0,
                 dp_mode: Optional[str] = None,
                 allreduce: Optional[str] = None):
        model_cfg.validate()
        self.model = model_cfg
        self.mesh_cfg = mesh_cfg
        self.seed = seed
        self._mesh_overrides = {k: v for k, v in
                                (("dp_mode", dp_mode), ("allreduce", allreduce))
                                if v is not None}
        self.bundle = registry.build(model_cfg)
        self._mesh = None
        self._params = None
        self._trainer = None
        self._trainer_key = None
        self._train_state = None
        self._stream = None            # (iterator, prefetcher) kept across
        self._stream_key = None        # train() calls: data must not replay
        self._engines: Dict[ServeConfig, Any] = {}
        self._last_engine = None

    # -- capabilities ------------------------------------------------------

    def capabilities(self) -> frozenset:
        """Declared decode-path contracts of the loaded family
        (subset of ``registry.CAPABILITIES``)."""
        return self.bundle.capabilities()

    def _require(self, cap: str):
        if cap not in self.capabilities():
            raise CapabilityError(
                f"{self.model.name} ({self.model.family}) doesn't {cap} yet: "
                f"declared capabilities are {sorted(self.capabilities())} — "
                "see ModelBundle.capabilities / ROADMAP.md")

    # -- mesh / params lifecycle ------------------------------------------

    def _train_mesh_cfg(self) -> MeshConfig:
        base = self.mesh_cfg or MeshConfig(shape=(1, 1),
                                           axis_names=("data", "model"))
        return dataclasses.replace(base, **self._mesh_overrides) \
            if self._mesh_overrides else base

    @property
    def mesh(self):
        """The jax device mesh (built on first use; None when meshless)."""
        if self.mesh_cfg is None:
            return None
        if self._mesh is None:
            import jax
            from repro.launch.mesh import build_mesh
            need = self.mesh_cfg.num_devices
            have = len(jax.devices())
            if need > have:
                raise ValueError(
                    f"mesh {self.mesh_cfg.shape} needs {need} devices but "
                    f"only {have} are visible; set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N (before the "
                    "first jax import) or pass --devices to the launchers")
            self._mesh = build_mesh(self._train_mesh_cfg())
        return self._mesh

    @property
    def params(self):
        """Current parameters: the trained state's params once ``train`` has
        run, otherwise a seeded init (shared by serve/generate)."""
        if self._train_state is not None:
            return self._train_state.params
        if self._params is None:
            import jax
            self._params = self.bundle.init_params(
                jax.random.PRNGKey(self.seed))
        return self._params

    # -- train -------------------------------------------------------------

    def train(self, steps: int = 50, *, data=None, seq_len: int = 64,
              global_batch: int = 8, optimizer: Optional[OptimizerConfig] = None,
              lr: Optional[float] = None, microbatch: int = 0,
              ckpt_dir: str = "", ckpt_every: int = 25, resume: bool = False,
              log_every: int = 0) -> TrainResult:
        """Run ``steps`` training steps of the sequential ``loss_fn``; the
        runtime injects broadcast init, gradient all-reduce and rank-sharded
        data (the paper's §III-D/F), plus checkpointing when ``ckpt_dir`` is
        set and elastic restore when ``resume`` is.

        ``data`` is a ``repro.data.readers.DataSet`` (default: synthetic
        tokens seeded from the session seed).  Repeated calls with the same
        shape/optimizer knobs continue from the current state.
        """
        self._require("train")
        import jax
        from repro.checkpoint.checkpoint import latest_step, save_checkpoint
        from repro.checkpoint.elastic import restore_elastic
        from repro.checkpoint.failures import StragglerMonitor
        from repro.core.transparent import TransparentTrainer
        from repro.data.pipeline import make_input_pipeline
        from repro.data.readers import synthetic_tokens

        opt = optimizer or OptimizerConfig(
            name="adam", lr=1e-3 if lr is None else lr)
        if optimizer is not None and lr is not None:
            opt = dataclasses.replace(opt, lr=lr)
        mesh_cfg = self._train_mesh_cfg()
        key = (seq_len, global_batch, opt, microbatch, mesh_cfg)
        if self._trainer is not None and self._trainer_key != key \
                and self._train_state is not None:
            import warnings
            warnings.warn(
                "train() knobs changed (shape/optimizer/mesh): the trained "
                "state is discarded and training restarts from a fresh "
                "init; pass the same knobs to continue a run",
                stacklevel=2)
        if self._trainer is None or self._trainer_key != key:
            run = RunConfig(
                model=self.model,
                shape=ShapeConfig("api", "train", seq_len, global_batch),
                mesh=mesh_cfg, optimizer=opt, seed=self.seed,
                microbatch=microbatch)
            self._trainer = TransparentTrainer.from_bundle(
                run, self.bundle, mesh=self.mesh)
            self._trainer_key = key
            self._train_state = None
        trainer = self._trainer

        # the data stream persists across train() calls: a continuation
        # consumes the *next* batches, never a replay of already-seen ones
        # (train(N) + train(N) == train(2N) for identical knobs)
        stream_key = (key, None if data is None else id(data))
        if self._stream is None or self._stream_key != stream_key:
            if self._stream is not None:
                self._stream[1].close()
            if data is None:
                data = synthetic_tokens(
                    self.model.vocab_size, seq_len,
                    num_samples=global_batch * 64, seed=self.seed,
                    rank=jax.process_index(),
                    world=max(jax.process_count(), 1))
            self._stream = make_input_pipeline(
                data, global_batch, trainer.mesh, trainer.dp_axes,
                seed=self.seed)
            self._stream_key = stream_key
        it, pf = self._stream

        if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
            self._train_state, start = restore_elastic(ckpt_dir, trainer)
        elif self._train_state is None:
            self._train_state = trainer.init(self.seed)
            start = 0
        else:
            start = int(jax.device_get(self._train_state.step))
        state = self._train_state
        monitor = StragglerMonitor()

        losses: List[float] = []
        t_start = time.time()
        step = start
        try:
            for batch in it:
                t0 = time.time()
                state, m = trainer.step(state, batch)
                monitor.record(time.time() - t0)
                step = int(m["step"])
                losses.append(float(m["loss"]))
                if log_every and (step % log_every == 0 or step == start + 1):
                    print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                          f"gnorm {float(m['grad_norm']):.3f}", flush=True)
                if ckpt_dir and ckpt_every and step % ckpt_every == 0:
                    save_checkpoint(ckpt_dir, state, step, blocking=False)
                if step >= start + steps:
                    break
        except BaseException:
            # stream state is unknown mid-batch: drop it so the next call
            # starts a fresh pipeline (the prefetch thread is daemon)
            self._stream[1].close()
            self._stream = None
            raise
        if ckpt_dir:
            save_checkpoint(ckpt_dir, state, step, blocking=True)
        self._train_state = state
        self._engines.clear()          # serving must see the new params
        self._last_engine = None
        metrics = {"loss": losses[-1] if losses else float("nan"),
                   "grad_norm": float(m["grad_norm"]) if losses else 0.0,
                   "step": step}
        return TrainResult(losses=losses, metrics=metrics, step=step,
                           elapsed_s=time.time() - t_start,
                           straggler=monitor.summary())

    # -- serve / generate --------------------------------------------------

    def _serve_cfg(self, prompts: Sequence[Sequence[int]],
                   max_new: Optional[int],
                   overrides: Dict[str, Any]) -> ServeConfig:
        """Resolve a ServeConfig: explicit knobs win, the rest auto-sizes to
        the submitted batch (longest prompt + generation budget)."""
        auto: Dict[str, Any] = {}
        if prompts:
            longest = max(len(p) for p in prompts)
            budget = max_new if max_new is not None else \
                overrides.get("max_new_tokens", 32)
            # bucket both knobs so varying batch sizes / prompt lengths
            # reuse one compiled engine instead of keying a new ServeConfig
            # (and a new fixed-shape XLA decode + KV pool) per distinct call
            auto["max_batch"] = min(
                8, 1 << max(len(prompts) - 1, 0).bit_length())
            need = longest + budget
            auto["max_seq_len"] = -(-need // _SEQ_BUCKET) * _SEQ_BUCKET
        if max_new is not None:
            auto["max_new_tokens"] = max_new
        auto.update(overrides)
        seq = auto.get("max_seq_len", ServeConfig.max_seq_len)
        if "page_size" not in auto:
            # auto-size pages to the model's layout: shrink for short
            # batches and to tile windowed-attention rings
            # (KVLayout.max_page_size); floor_pow2 keeps the default
            # enable_prefix_cache block hashing valid
            from repro.configs.base import floor_pow2
            cap = floor_pow2(seq)
            layout = self.bundle.kv_layout
            if layout is not None:
                cap = min(cap, layout.max_page_size())
            if ServeConfig.page_size > cap:
                auto["page_size"] = cap
        return ServeConfig(**auto)

    def _drop_engine(self, key) -> None:
        """Retire one cached engine, invalidating its prefix cache first —
        a retired pool's cached pages must never survive into a later
        engine's view of 'cached' state."""
        eng = self._engines.pop(key)
        if getattr(eng, "paged", False):
            eng.pool.clear_prefix_cache()
        if self._last_engine is eng:
            self._last_engine = None

    def _engine_for(self, serve_cfg: ServeConfig):
        from repro.serving import ServingEngine
        # switching kv_layout / kv_dtype (or mutating the model's
        # attn_kind) on a live Session retires every engine built for a
        # different layout: a stale ServeConfig-keyed engine would
        # otherwise survive with an incompatible pool (and a prefix cache
        # the caller believes gone)
        for key in [k for k, e in self._engines.items()
                    if k.kv_layout != serve_cfg.kv_layout
                    or k.kv_dtype != serve_cfg.kv_dtype
                    or e.model_cfg.attn_kind != self.model.attn_kind]:
            self._drop_engine(key)
        eng = self._engines.pop(serve_cfg, None)
        if eng is None:
            eng = ServingEngine(self.model, serve_cfg, params=self.params,
                                mesh_cfg=self.mesh_cfg, seed=self.seed)
        self._engines[serve_cfg] = eng          # re-insert = LRU touch
        while len(self._engines) > _MAX_ENGINES:
            self._drop_engine(next(iter(self._engines)))
        self._last_engine = eng
        return eng

    @property
    def engine(self):
        """The most recently used serving engine (metrics live here)."""
        return self._last_engine

    @property
    def tracer(self):
        """The last-used engine's ``repro.obs`` tracer (``NULL_TRACER``
        when that engine ran untraced; None before any serve call).  Turn
        tracing on per call: ``session.serve(..., trace=True)``."""
        return None if self._last_engine is None else self._last_engine.tracer

    def save_trace(self, path: str) -> Optional[str]:
        """Write the last serve's Chrome trace JSON (Perfetto-loadable —
        ui.perfetto.dev / chrome://tracing); None when no engine has run
        or the last serve was untraced."""
        if self._last_engine is None:
            return None
        return self._last_engine.save_trace(path)

    def serve(self, requests: Sequence[Sequence[int]], *,
              max_new: Optional[int] = None, stream=None,
              serve_cfg: Optional[ServeConfig] = None,
              sampling=None, **serve_overrides) -> List[List[int]]:
        """Continuous-batching generation for a closed batch of prompts
        (lists of token ids); returns one token list per prompt, in order.

        Pass a full ``serve_cfg`` for total control, or individual
        ``ServeConfig`` field overrides as keyword arguments
        (``policy="priority"``, ``kv_layout="paged"``,
        ``enable_prefix_cache=False``, ``prefill_chunk_tokens=256``, ...).
        ``sampling`` is one ``repro.serving.SamplingParams`` for every
        prompt or a per-prompt list (None = greedy).  Decode — greedy or
        sampled — is token-identical to serving each prompt alone:
        sampling keys its PRNG per request by (seed, token index), never
        by batch state.
        """
        self._require("serve")
        prompts = [list(map(int, p)) for p in requests]
        if serve_cfg is not None:
            cfg = serve_cfg.replace(**serve_overrides) if serve_overrides \
                else serve_cfg
        else:
            cfg = self._serve_cfg(prompts, max_new, serve_overrides)
        eng = self._engine_for(cfg)
        return eng.generate(prompts, max_new, stream=stream,
                            sampling=sampling)

    def generate(self, prompts, max_new: int = 16, *, stream=None,
                 sampling=None, **serve_overrides):
        """One-shot convenience over :meth:`serve`: accepts one prompt (flat
        token sequence -> returns one token list) or a batch of prompts."""
        self._require("serve")
        seq = list(prompts)
        single = bool(seq) and all(isinstance(t, (int, np.integer))
                                   for t in seq)
        batch = [seq] if single else seq
        outs = self.serve(batch, max_new=max_new, stream=stream,
                          sampling=sampling, **serve_overrides)
        return outs[0] if single else outs

    def __repr__(self):
        mesh = "x".join(map(str, self.mesh_cfg.shape)) if self.mesh_cfg \
            else "single-device"
        return (f"Session({self.model.name}, mesh={mesh}, "
                f"capabilities={sorted(self.capabilities())})")


def load(arch: str, *, smoke: bool = False, mesh: MeshLike = None,
         seed: int = 0, dp_mode: Optional[str] = None,
         allreduce: Optional[str] = None,
         require: Iterable[str] = (), **overrides) -> Session:
    """The one supported entrypoint: ``load(arch) -> Session``.

    ``arch``       any registry architecture (``repro.configs.ALL_ARCHS``);
    ``smoke``      CPU-sized config variant;
    ``mesh``       ``"DxM"`` string / shape tuple / ``MeshConfig`` / None —
                   the *only* distribution knob a user script needs;
    ``dp_mode`` / ``allreduce``  training placement / reduction strategy
                   (forwarded into the MeshConfig);
    ``require``    capability names that must be declared *now* (e.g.
                   ``("serve",)``) — fail at load, not mid-run;
    ``overrides``  ``ModelConfig.replace`` fields (``num_layers=2``, ...).
    """
    cfg = get_config(arch, smoke=smoke)
    if overrides:
        cfg = cfg.replace(**overrides)
    sess = Session(cfg, parse_mesh(mesh), seed=seed, dp_mode=dp_mode,
                   allreduce=allreduce)
    for cap in require:
        sess._require(cap)
    return sess
