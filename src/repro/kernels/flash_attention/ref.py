"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [BH, Sq, hd]; k/v: [BKV, Sk, hd] (GQA: BH = G * BKV).

    Materializes the full score matrix — memory-unbounded, correctness only.
    """
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    kf = jnp.repeat(k, G, axis=0)
    vf = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * (hd ** -0.5)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkh->bqh", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)
