"""jit'd public wrapper: model-layout [B,S,H,hd] flash attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B,S,H,hd]; k/v: [B,T,KV,hd] -> [B,S,H,hd] (self-attention layout
    used by repro.models.attention)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
