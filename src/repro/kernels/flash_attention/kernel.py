"""Flash attention TPU kernel (pl.pallas_call + BlockSpec VMEM tiling).

TPU adaptation of the (GPU-origin) FlashAttention schedule: instead of a
warp-level softmax pipeline, blocks are sized for the MXU (128-aligned
q/k tiles), the online-softmax state (m, l, acc) lives in VMEM scratch and
persists across the *sequential* kv-block grid dimension, and causal /
sliding-window skipping is grid-level (``pl.when``) so skipped tiles cost
no MXU cycles.

Layouts: q [BH, Sq, hd] (heads flattened into the grid), k/v [BKV, Sk, hd];
GQA is handled in the BlockSpec index maps (kv row = q row // group).

Grid: (BH, nq, nk) with nk sequential ("arbitrary") — m/l/acc scratch carry.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import NEG_INF, CompilerParams as _CompilerParams


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nk: int, seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # grid-level skip: tile entirely above the causal diagonal or entirely
    # outside the sliding window
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window:
        run = jnp.logical_and(run,
                              k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # [bq, hd]
        k = k_ref[0].astype(jnp.float32)                 # [bk, hd]
        v = v_ref[0].astype(jnp.float32)                 # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                            # [bq, bk]
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: [BH, Sq, hd]; k/v: [BKV, Sk, hd]; BH % BKV == 0 (GQA groups).

    Returns [BH, Sq, hd] in q.dtype.
    """
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    assert BH % BKV == 0, (BH, BKV)
    G = BH // BKV
    scale = hd ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh // G, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, iq, ik: (bh // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
