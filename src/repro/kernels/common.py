"""Shared constants/shims for the Pallas kernel families."""
from __future__ import annotations

import jax.numpy as jnp
import jax.experimental.pallas.tpu as pltpu

# Large-negative mask value safe to exponentiate in fp32 (exp -> exactly 0)
NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# renamed TPUCompilerParams -> CompilerParams across jax releases
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
