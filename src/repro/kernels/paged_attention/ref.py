"""Pure-jnp oracles for the paged-attention decode kernels.

Layouts (the serving pool's native shapes, one per ``KVLayout``):
  * ``q``           [slots, H, hd]        — one query token per decode slot
  * ``k/v_pages``   [P, ps, KV, hd]       — per-head page pool ("kv" and
                                            ring "window" layouts; page 0
                                            is the reserved trash page)
  * ``ckv/krope_pages`` [P, ps, R] / [P, ps, rp] — latent page pool (MLA)
  * ``page_table``  [slots, n] int32      — per-slot page ids; entries past
                                            a slot's held pages point at
                                            page 0
  * ``lengths``     [slots] int32         — tokens cached per slot

Position mapping is the layout's:
  * contiguous — token t of slot s lives at page ``page_table[s, t // ps]``,
    offset ``t % ps``; validity is ``index < length``.
  * ring (``window > 0``) — the table is a ring of ``window // ps`` cells;
    ring index i holds the *latest* absolute position ``p = cur -
    ((cur - i) mod window)`` with ``cur = length - 1``; validity is
    ``p >= 0`` (the formula already confines p to the window, which is
    exactly the sliding-window mask — out-of-window cells whose pages
    rotated to trash resolve to positions the mask excludes).

GQA head convention matches ``repro.models.attention``: head h = kv-head
``h // G`` (reshape H -> (KV, G)).  These materialize the fully gathered
[slots, n*ps] score matrix — correctness only; the Pallas kernels only
ever touch pages a slot actually holds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def ring_positions(lengths, n_tokens: int, window: int):
    """Absolute position held by each ring index (see module docstring).

    lengths [slots] int32 -> ([slots, n_tokens] positions, validity)."""
    cur = lengths[:, None] - 1
    i = jnp.arange(n_tokens)[None, :]
    p = cur - jnp.mod(cur - i, window)
    return p, p >= 0


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                        window: int = 0):
    """Returns [slots, H, hd] in q.dtype.  ``window > 0`` selects the ring
    layout's position mapping (sliding-window mask included)."""
    S, H, hd = q.shape
    _, ps, KV, _ = k_pages.shape
    n = page_table.shape[1]
    G = H // KV
    scale = hd ** -0.5
    k = k_pages[page_table].reshape(S, n * ps, KV, hd)     # gather-all
    v = v_pages[page_table].reshape(S, n * ps, KV, hd)
    if window:
        _, valid = ring_positions(lengths, n * ps, window)
    else:
        valid = jnp.arange(n * ps)[None, :] < lengths[:, None]  # [S, n*ps]
    q_ = q.reshape(S, KV, G, hd)
    s = jnp.einsum("skgh,stkh->skgt", q_.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("skgt,stkh->skgh", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(S, H, hd).astype(q.dtype)


def paged_mla_attention_ref(q_lat, q_rope, ckv_pages, krope_pages,
                            page_table, lengths, *, scale: float):
    """Absorbed MLA decode against latent pages (contiguous layout).

    q_lat [slots, H, R] — queries absorbed through W_uk into the latent
    space; q_rope [slots, H, rp]; ckv_pages [P, ps, R]; krope_pages
    [P, ps, rp].  ``scale`` is the *qk-dimension* softmax scale (the latent
    rank is not the score dimension).  Returns the latent-space output
    [slots, H, R] in q_lat.dtype — the caller up-projects through W_uv.
    """
    S, H, R = q_lat.shape
    _, ps, _ = ckv_pages.shape
    n = page_table.shape[1]
    ckv = ckv_pages[page_table].reshape(S, n * ps, R)
    kr = krope_pages[page_table].reshape(S, n * ps, krope_pages.shape[-1])
    s = jnp.einsum("shr,str->sht", q_lat.astype(jnp.float32),
                   ckv.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("shr,str->sht", q_rope.astype(jnp.float32),
                       kr.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    valid = jnp.arange(n * ps)[None, :] < lengths[:, None]   # [S, n*ps]
    s = jnp.where(valid[:, None, :], s * scale, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("sht,str->shr", p, ckv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q_lat.dtype)
