"""Pure-jnp oracle for the paged-attention decode kernel.

Layouts (the serving pool's native shapes):
  * ``q``           [slots, H, hd]        — one query token per decode slot
  * ``k/v_pages``   [P, ps, KV, hd]       — global page pool (P pages of ps
                                            tokens; page 0 is the reserved
                                            trash page, never allocated)
  * ``page_table``  [slots, n] int32      — per-slot page ids; entries past a
                                            slot's held pages point at page 0
  * ``lengths``     [slots] int32         — tokens valid per slot; token t of
                                            slot s lives at page
                                            ``page_table[s, t // ps]``,
                                            offset ``t % ps``

GQA head convention matches ``repro.models.attention``: head h = kv-head
``h // G`` (reshape H -> (KV, G)).  Materializes the fully gathered
[slots, n*ps] score matrix — correctness only; the Pallas kernel only ever
touches pages a slot actually holds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """Returns [slots, H, hd] in q.dtype."""
    S, H, hd = q.shape
    _, ps, KV, _ = k_pages.shape
    n = page_table.shape[1]
    G = H // KV
    scale = hd ** -0.5
    k = k_pages[page_table].reshape(S, n * ps, KV, hd)     # gather-all
    v = v_pages[page_table].reshape(S, n * ps, KV, hd)
    q_ = q.reshape(S, KV, G, hd)
    s = jnp.einsum("skgh,stkh->skgt", q_.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(n * ps)[None, :] < lengths[:, None]  # [S, n*ps]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("skgt,stkh->skgh", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(S, H, hd).astype(q.dtype)
