"""Pure-jnp oracles for the paged-attention decode kernels.

Layouts (the serving pool's native shapes, one per ``KVLayout``):
  * ``q``           [slots, H, hd]        — one query token per decode slot
  * ``k/v_pages``   [P, ps, KV, hd]       — per-head page pool ("kv" and
                                            ring "window" layouts; page 0
                                            is the reserved trash page)
  * ``ckv/krope_pages`` [P, ps, R] / [P, ps, rp] — latent page pool (MLA)
  * ``page_table``  [slots, n] int32      — per-slot page ids; entries past
                                            a slot's held pages point at
                                            page 0
  * ``lengths``     [slots] int32         — tokens cached per slot

Position mapping is the layout's:
  * contiguous — token t of slot s lives at page ``page_table[s, t // ps]``,
    offset ``t % ps``; validity is ``index < length``.
  * ring (``window > 0``) — the table is a ring of ``window // ps`` cells;
    ring index i holds the *latest* absolute position ``p = cur -
    ((cur - i) mod window)`` with ``cur = length - 1``; validity is
    ``p >= 0`` (the formula already confines p to the window, which is
    exactly the sliding-window mask — out-of-window cells whose pages
    rotated to trash resolve to positions the mask excludes).

GQA head convention matches ``repro.models.attention``: head h = kv-head
``h // G`` (reshape H -> (KV, G)).  These materialize the fully gathered
[slots, n*ps] score matrix — correctness only; the Pallas kernels only
ever touch pages a slot actually holds.

Quantized (int8) pools pass ``k_scale``/``v_scale`` [P, ps, KV] bf16 (one
symmetric scale per (page, offset, kv-head) row, widened to fp32 on read).  The oracles mirror the
kernels' *fused* dequant exactly — raw int8 scores are computed first and
multiplied by the key's scale per column, probabilities are multiplied by
the value's scale per row before the PV product; fp pages are never
materialized — so kernel-on vs kernel-off stays token-identical for
quantized layouts.  MLA latent oracles take no scales (the layout seam
rejects quantized latents).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def ring_positions(lengths, n_tokens: int, window: int):
    """Absolute position held by each ring index (see module docstring).

    lengths [slots] int32 -> ([slots, n_tokens] positions, validity)."""
    cur = lengths[:, None] - 1
    i = jnp.arange(n_tokens)[None, :]
    p = cur - jnp.mod(cur - i, window)
    return p, p >= 0


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                        window: int = 0, k_scale=None, v_scale=None):
    """Returns [slots, H, hd] in q.dtype.  ``window > 0`` selects the ring
    layout's position mapping (sliding-window mask included).  ``k_scale``/
    ``v_scale`` [P, ps, KV] mark int8 pages — fused dequant, mirroring the
    kernel (see module docstring)."""
    S, H, hd = q.shape
    _, ps, KV, _ = k_pages.shape
    n = page_table.shape[1]
    G = H // KV
    scale = hd ** -0.5
    k = k_pages[page_table].reshape(S, n * ps, KV, hd)     # gather-all
    v = v_pages[page_table].reshape(S, n * ps, KV, hd)
    if window:
        _, valid = ring_positions(lengths, n * ps, window)
    else:
        valid = jnp.arange(n * ps)[None, :] < lengths[:, None]  # [S, n*ps]
    q_ = q.reshape(S, KV, G, hd)
    s = jnp.einsum("skgh,stkh->skgt", q_.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        ks = k_scale[page_table].astype(jnp.float32) \
                                .reshape(S, n * ps, KV)    # [S, t, KV]
        s = s * ks.transpose(0, 2, 1)[:, :, None, :]       # per key column
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        vs = v_scale[page_table].astype(jnp.float32) \
                                .reshape(S, n * ps, KV)
        p = p * vs.transpose(0, 2, 1)[:, :, None, :]       # per value row
    out = jnp.einsum("skgt,stkh->skgh", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(S, H, hd).astype(q.dtype)


def paged_mla_attention_ref(q_lat, q_rope, ckv_pages, krope_pages,
                            page_table, lengths, *, scale: float):
    """Absorbed MLA decode against latent pages (contiguous layout).

    q_lat [slots, H, R] — queries absorbed through W_uk into the latent
    space; q_rope [slots, H, rp]; ckv_pages [P, ps, R]; krope_pages
    [P, ps, rp].  ``scale`` is the *qk-dimension* softmax scale (the latent
    rank is not the score dimension).  Returns the latent-space output
    [slots, H, R] in q_lat.dtype — the caller up-projects through W_uv.
    """
    S, H, R = q_lat.shape
    _, ps, _ = ckv_pages.shape
    n = page_table.shape[1]
    ckv = ckv_pages[page_table].reshape(S, n * ps, R)
    kr = krope_pages[page_table].reshape(S, n * ps, krope_pages.shape[-1])
    s = jnp.einsum("shr,str->sht", q_lat.astype(jnp.float32),
                   ckv.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("shr,str->sht", q_rope.astype(jnp.float32),
                       kr.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    valid = jnp.arange(n * ps)[None, :] < lengths[:, None]   # [S, n*ps]
    s = jnp.where(valid[:, None, :], s * scale, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("sht,str->shr", p, ckv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q_lat.dtype)


# ---------------------------------------------------------------------------
# Chunked-prefill oracles (one bucketed chunk of a single request)
#
# q is [S, H, hd] (H = KV * G); page_table is the request's single row [n];
# start / n_valid are traced scalars — query i holds absolute position
# ``start + i``, the bucket tail (i >= n_valid) is padding.  Bucket-tail
# output rows are garbage in both the oracle and the kernel (the kernel
# skips them at grid level and emits 0) — callers only ever read rows
# < n_valid, and tests must compare only those.
# ---------------------------------------------------------------------------

def _prefill_attend(q, k, v, valid, scale, k_scale=None, v_scale=None):
    """Masked full-softmax core: q [S, KV, G, hd], k/v [T, KV, hd],
    valid [S, T] -> [S, KV*G, hd].  Optional per-key-row dequant scales
    k_scale/v_scale [T, KV] (fused, matching the kernels: raw scores *
    key scale, probabilities * value scale)."""
    S, KV, G, hd = q.shape
    s = jnp.einsum("skgh,tkh->skgt", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        s = s * k_scale.T[None, :, None, :]                # per key column
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * v_scale.T[None, :, None, :]                # per value row
    out = jnp.einsum("skgt,tkh->skgh", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(S, KV * G, hd)


def paged_prefill_ref(q, k_pages, v_pages, page_table, start, n_valid, *,
                      k_scale=None, v_scale=None):
    """Contiguous-layout chunked prefill: the pages already hold the
    chunk's K/V (positions start..start+n_valid-1), so queries attend the
    gathered pages under the written bound AND the causal horizon.
    ``k_scale``/``v_scale`` [P, ps, KV] mark int8 pages (fused dequant).
    Returns [S, H, hd] in q.dtype."""
    S, H, hd = q.shape
    _, ps, KV, _ = k_pages.shape
    n = page_table.shape[0]
    k = k_pages[page_table].reshape(n * ps, KV, hd)
    v = v_pages[page_table].reshape(n * ps, KV, hd)
    ks = (k_scale[page_table].astype(jnp.float32).reshape(n * ps, KV)
          if k_scale is not None else None)
    vs = (v_scale[page_table].astype(jnp.float32).reshape(n * ps, KV)
          if v_scale is not None else None)
    kidx = jnp.arange(n * ps)
    qpos = start + jnp.arange(S)
    valid = (kidx[None, :] < start + n_valid) \
        & (kidx[None, :] <= qpos[:, None])
    out = _prefill_attend(q.reshape(S, KV, H // KV, hd), k, v, valid,
                          hd ** -0.5, ks, vs)
    return out.astype(q.dtype)


def paged_ring_prefill_ref(q, k_pages, v_pages, chunk_k, chunk_v,
                           page_table, start, n_valid, *, window: int,
                           k_scale=None, v_scale=None):
    """Ring-layout chunked prefill, snapshot-before-write semantics: the
    pages are the PRE-write ring snapshot (the chunk's writes wrap onto
    cells its own early queries still need) and the chunk's own keys/
    values ride along as [S, KV, hd] operands.  Key positions follow the
    ring formula for the snapshot and ``start + j`` for the chunk; the
    sliding-window mask excludes every wrapped-over snapshot cell.
    ``k_scale``/``v_scale`` [P, ps, KV] mark int8 *snapshot* pages — the
    chunk operands stay fp, so their fused scale is 1.
    Returns [S, H, hd] in q.dtype."""
    S, H, hd = q.shape
    _, ps, KV, _ = k_pages.shape
    n = page_table.shape[0]
    ring_k = k_pages[page_table].reshape(n * ps, KV, hd)
    ring_v = v_pages[page_table].reshape(n * ps, KV, hd)
    cur = start - 1
    i = jnp.arange(n * ps)
    ring_pos = cur - jnp.mod(cur - i, window)       # < 0 = never written
    if k_scale is not None:
        # snapshot rows carry their page scales; chunk rows are fp (= 1)
        kk = jnp.concatenate([ring_k.astype(jnp.float32),
                              chunk_k.astype(jnp.float32)], axis=0)
        vv = jnp.concatenate([ring_v.astype(jnp.float32),
                              chunk_v.astype(jnp.float32)], axis=0)
        ones = jnp.ones((S, KV), jnp.float32)
        ks = jnp.concatenate(
            [k_scale[page_table].astype(jnp.float32).reshape(n * ps, KV),
             ones], axis=0)
        vs = jnp.concatenate(
            [v_scale[page_table].astype(jnp.float32).reshape(n * ps, KV),
             ones], axis=0)
    else:
        kk = jnp.concatenate([ring_k, chunk_k.astype(ring_k.dtype)], axis=0)
        vv = jnp.concatenate([ring_v, chunk_v.astype(ring_v.dtype)], axis=0)
        ks = vs = None
    k_pos = jnp.concatenate([ring_pos, start + jnp.arange(S)])
    k_ok = jnp.concatenate([ring_pos >= 0, jnp.arange(S) < n_valid])
    qpos = start + jnp.arange(S)
    valid = k_ok[None, :] & (k_pos[None, :] <= qpos[:, None]) \
        & (k_pos[None, :] > qpos[:, None] - window)
    out = _prefill_attend(q.reshape(S, KV, H // KV, hd), kk, vv, valid,
                          hd ** -0.5, ks, vs)
    return out.astype(q.dtype)


def paged_mla_prefill_ref(q_lat, q_rope, ckv_pages, krope_pages,
                          page_table, start, n_valid, *, scale: float):
    """Absorbed-MLA chunked prefill against latent pages (contiguous).
    q_lat [S, H, R] — queries absorbed through W_uk; pages hold the
    chunk's freshly written latents.  Returns the latent-space output
    [S, H, R] in q_lat.dtype — the caller up-projects through W_uv."""
    S, H, R = q_lat.shape
    _, ps, _ = ckv_pages.shape
    n = page_table.shape[0]
    ckv = ckv_pages[page_table].reshape(n * ps, R)
    kr = krope_pages[page_table].reshape(n * ps, krope_pages.shape[-1])
    s = jnp.einsum("shr,tr->sht", q_lat.astype(jnp.float32),
                   ckv.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("shr,tr->sht", q_rope.astype(jnp.float32),
                       kr.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    kidx = jnp.arange(n * ps)
    qpos = start + jnp.arange(S)
    valid = (kidx[None, :] < start + n_valid) \
        & (kidx[None, :] <= qpos[:, None])
    s = jnp.where(valid[:, None, :], s * scale, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("sht,tr->shr", p, ckv.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q_lat.dtype)
