"""jit'd public wrappers: paged attention in the serving pool's layouts.

Dispatch mirrors ``flash_attention``: the traced jnp path (ref semantics,
gather-all) is the portable default the serving engine runs everywhere; the
Pallas kernels (``use_kernel=True``) are the TPU fast path whose HBM
traffic scales with pages actually held.  Off-TPU, ``use_kernel=True``
transparently runs the kernels in interpret mode (the backend selection
the engine's ``ServeConfig.use_pallas`` override and the CI smoke job rely
on), so kernel code paths stay exercised everywhere.

Decode wrappers, one per page geometry: ``paged_attention`` covers the
per-head k/v layouts (contiguous "kv" and ring "window" — ``window > 0``
flips the position mapping), and ``paged_mla_attention`` the latent
ckv/krope layout (absorbed MLA decode; scores and output stay in the
latent space).  Chunked-prefill wrappers follow the same contract for one
request's bucketed chunk: ``paged_prefill`` (contiguous; pages already
hold the chunk's K/V), ``paged_ring_prefill`` (snapshot-before-write ring
semantics; the chunk's own K/V ride along), ``paged_mla_prefill``
(absorbed latent queries, latent output).  All share the head conventions
of ``repro.models.attention``.

Quantized (int8) pools pass their per-(page, offset, kv-head) bf16 scale
leaves as optional ``k_scale``/``v_scale`` operands ([P, ps, KV]; None =
fp pages).  Kernel and ref apply the *identical* fused math — raw int8
scores scaled per key column, probabilities scaled per value row before
the PV product — so kernel-on vs kernel-off stays token-identical for
quantized layouts too.  MLA latent pages never quantize (the layout seam
rejects the combination), so the MLA wrappers take no scales."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (paged_attention_kernel,
                                                  paged_mla_kernel,
                                                  paged_mla_prefill_kernel,
                                                  paged_prefill_kernel,
                                                  paged_ring_prefill_kernel)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_mla_attention_ref,
                                               paged_mla_prefill_ref,
                                               paged_prefill_ref,
                                               paged_ring_prefill_ref)


def _interp(interpret: bool) -> bool:
    """Kernels only lower on TPU; everywhere else ``use_kernel=True`` means
    the Pallas interpreter (correctness-identical, CI-exercisable)."""
    return interpret or jax.default_backend() != "tpu"


def _meta(start, n_valid):
    return jnp.stack([jnp.asarray(start, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])


@functools.partial(jax.jit,
                   static_argnames=("window", "use_kernel", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    window: int = 0, k_scale=None, v_scale=None,
                    use_kernel: bool = False, interpret: bool = False):
    """q: [slots, H, hd]; k/v_pages: [P, ps, KV, hd]; page_table:
    [slots, n_table] int32 (pad with 0, the trash page); lengths: [slots]
    int32 (valid tokens per slot).  ``window > 0`` selects the ring-cell
    position mapping (sliding-window mask included).  ``k_scale``/
    ``v_scale`` [P, ps, KV] bf16 mark int8 pages (dequant fused into the
    softmax accumulation).  Returns [slots, H, hd] in q.dtype."""
    slots, H, hd = q.shape
    KV = k_pages.shape[2]
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, page_table, lengths,
                                   window=window, k_scale=k_scale,
                                   v_scale=v_scale)
    G = H // KV
    out = paged_attention_kernel(q.reshape(slots, KV, G, hd), k_pages,
                                 v_pages, page_table, lengths,
                                 window=window, k_scale=k_scale,
                                 v_scale=v_scale,
                                 interpret=_interp(interpret))
    return out.reshape(slots, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("scale", "use_kernel", "interpret"))
def paged_mla_attention(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                        lengths, *, scale: float, use_kernel: bool = False,
                        interpret: bool = False):
    """Absorbed MLA decode against latent pages.  q_lat: [slots, H, R]
    (queries absorbed through W_uk); q_rope: [slots, H, rp]; ckv_pages:
    [P, ps, R]; krope_pages: [P, ps, rp]; ``scale`` the qk-dimension
    softmax scale.  Returns the latent-space output [slots, H, R] — the
    caller up-projects through W_uv."""
    if not use_kernel:
        return paged_mla_attention_ref(q_lat, q_rope, ckv_pages,
                                       krope_pages, page_table, lengths,
                                       scale=scale)
    return paged_mla_kernel(q_lat, q_rope, ckv_pages, krope_pages,
                            page_table, lengths, scale=scale,
                            interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_prefill(q, k_pages, v_pages, page_table, start, n_valid, *,
                  k_scale=None, v_scale=None, use_kernel: bool = False,
                  interpret: bool = False):
    """Contiguous-layout chunked prefill.  q: [S, H, hd] — one request's
    bucketed chunk (post-rope; query i holds absolute position
    ``start + i``); k/v_pages: [P, ps, KV, hd] — the pool AFTER the
    chunk's K/V were scattered in; page_table: [n] int32 — the request's
    row (0-padded tail = trash); start / n_valid traced scalars.  Rows
    past ``n_valid`` are bucket padding — their output is undefined and
    must not be read.  ``k_scale``/``v_scale`` [P, ps, KV] bf16 mark int8
    pages.  Returns [S, H, hd] in q.dtype."""
    S, H, hd = q.shape
    if not use_kernel:
        return paged_prefill_ref(q, k_pages, v_pages, page_table, start,
                                 n_valid, k_scale=k_scale, v_scale=v_scale)
    KV = k_pages.shape[2]
    out = paged_prefill_kernel(q.reshape(S, KV, H // KV, hd), k_pages,
                               v_pages, page_table, _meta(start, n_valid),
                               k_scale=k_scale, v_scale=v_scale,
                               interpret=_interp(interpret))
    return out.reshape(S, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("window", "use_kernel", "interpret"))
def paged_ring_prefill(q, k_pages, v_pages, chunk_k, chunk_v, page_table,
                       start, n_valid, *, window: int, k_scale=None,
                       v_scale=None, use_kernel: bool = False,
                       interpret: bool = False):
    """Ring-layout (sliding-window/local) chunked prefill with
    snapshot-before-write semantics: k/v_pages are the pool BEFORE the
    chunk's writes and chunk_k/chunk_v [S, KV, hd] are the chunk's own
    post-rope keys/values (its writes wrap onto ring cells its early
    queries still need, so they must not be read back through the table).
    ``k_scale``/``v_scale`` [P, ps, KV] bf16 mark int8 *snapshot* pages —
    the chunk operands always stay fp (freshly projected, never read back
    from the pool).  Returns [S, H, hd] in q.dtype."""
    S, H, hd = q.shape
    if not use_kernel:
        return paged_ring_prefill_ref(q, k_pages, v_pages, chunk_k,
                                      chunk_v, page_table, start, n_valid,
                                      window=window, k_scale=k_scale,
                                      v_scale=v_scale)
    KV = k_pages.shape[2]
    out = paged_ring_prefill_kernel(q.reshape(S, KV, H // KV, hd), k_pages,
                                    v_pages, chunk_k, chunk_v, page_table,
                                    _meta(start, n_valid), window=window,
                                    k_scale=k_scale, v_scale=v_scale,
                                    interpret=_interp(interpret))
    return out.reshape(S, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("scale", "use_kernel", "interpret"))
def paged_mla_prefill(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                      start, n_valid, *, scale: float,
                      use_kernel: bool = False, interpret: bool = False):
    """Absorbed-MLA chunked prefill against latent pages (contiguous).
    q_lat: [S, H, R] — the chunk's queries absorbed through W_uk; q_rope:
    [S, H, rp]; ckv/krope_pages hold the chunk's freshly written latents;
    ``scale`` the qk-dimension softmax scale.  Pages stream compressed —
    per-head K/V are never materialized.  Returns the latent-space output
    [S, H, R] in q_lat.dtype — the caller up-projects through W_uv."""
    if not use_kernel:
        return paged_mla_prefill_ref(q_lat, q_rope, ckv_pages, krope_pages,
                                     page_table, start, n_valid,
                                     scale=scale)
    return paged_mla_prefill_kernel(q_lat, q_rope, ckv_pages, krope_pages,
                                    page_table, _meta(start, n_valid),
                                    scale=scale,
                                    interpret=_interp(interpret))
