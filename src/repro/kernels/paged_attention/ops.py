"""jit'd public wrapper: paged-attention decode in the serving pool's layout.

Dispatch mirrors ``flash_attention``: the traced jnp path (ref semantics,
gather-all) is the portable default the serving engine runs everywhere; the
Pallas kernel (``use_kernel=True``) is the TPU fast path whose HBM traffic
scales with pages actually held.  Both share the head convention of
``repro.models.attention`` (H reshaped to (KV, G))."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    use_kernel: bool = False, interpret: bool = False):
    """q: [slots, H, hd]; k/v_pages: [P, ps, KV, hd]; page_table:
    [slots, n_table] int32 (pad with 0, the trash page); lengths: [slots]
    int32 (valid tokens per slot).  Returns [slots, H, hd] in q.dtype."""
    slots, H, hd = q.shape
    KV = k_pages.shape[2]
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, page_table, lengths)
    G = H // KV
    out = paged_attention_kernel(q.reshape(slots, KV, G, hd), k_pages,
                                 v_pages, page_table, lengths,
                                 interpret=interpret)
    return out.reshape(slots, H, hd)
