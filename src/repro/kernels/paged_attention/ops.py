"""jit'd public wrappers: paged-attention decode in the serving pool's
layouts.

Dispatch mirrors ``flash_attention``: the traced jnp path (ref semantics,
gather-all) is the portable default the serving engine runs everywhere; the
Pallas kernels (``use_kernel=True``) are the TPU fast path whose HBM
traffic scales with pages actually held.  One wrapper per page geometry:
``paged_attention`` covers the per-head k/v layouts (contiguous "kv" and
ring "window" — ``window > 0`` flips the position mapping), and
``paged_mla_attention`` the latent ckv/krope layout (absorbed MLA decode;
scores and output stay in the latent space).  All share the head
conventions of ``repro.models.attention``."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import (paged_attention_kernel,
                                                  paged_mla_kernel)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_mla_attention_ref)


@functools.partial(jax.jit,
                   static_argnames=("window", "use_kernel", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    window: int = 0, use_kernel: bool = False,
                    interpret: bool = False):
    """q: [slots, H, hd]; k/v_pages: [P, ps, KV, hd]; page_table:
    [slots, n_table] int32 (pad with 0, the trash page); lengths: [slots]
    int32 (valid tokens per slot).  ``window > 0`` selects the ring-cell
    position mapping (sliding-window mask included).  Returns
    [slots, H, hd] in q.dtype."""
    slots, H, hd = q.shape
    KV = k_pages.shape[2]
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, page_table, lengths,
                                   window=window)
    G = H // KV
    out = paged_attention_kernel(q.reshape(slots, KV, G, hd), k_pages,
                                 v_pages, page_table, lengths,
                                 window=window, interpret=interpret)
    return out.reshape(slots, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("scale", "use_kernel", "interpret"))
def paged_mla_attention(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                        lengths, *, scale: float, use_kernel: bool = False,
                        interpret: bool = False):
    """Absorbed MLA decode against latent pages.  q_lat: [slots, H, R]
    (queries absorbed through W_uk); q_rope: [slots, H, rp]; ckv_pages:
    [P, ps, R]; krope_pages: [P, ps, rp]; ``scale`` the qk-dimension
    softmax scale.  Returns the latent-space output [slots, H, R] — the
    caller up-projects through W_uv."""
    if not use_kernel:
        return paged_mla_attention_ref(q_lat, q_rope, ckv_pages,
                                       krope_pages, page_table, lengths,
                                       scale=scale)
    return paged_mla_kernel(q_lat, q_rope, ckv_pages, krope_pages,
                            page_table, lengths, scale=scale,
                            interpret=interpret)
