"""Paged-attention TPU kernels (vLLM-style, scalar-prefetched pages).

One decode step attends each slot's single query against a cache scattered
across a global page pool; one prefill chunk attends a *block of causal
queries* against the same pages.  The page table is a *scalar-prefetch*
operand (``pltpu.PrefetchScalarGridSpec``): BlockSpec index maps read it to
decide which physical page to DMA into VMEM for each grid step, so HBM
traffic is ``pages_held``, not ``slots x max_pages`` — the whole point of
paging.

Decode kernels, one per page geometry (see ``repro.serving.layouts``):

  * ``paged_attention_kernel`` — per-head k/v pages for GQA, covering both
    the contiguous ("kv") and ring-wrapped ("window") layouts.  For the
    ring, a cell's absolute position is arithmetic, not storage:
    ``p = cur - ((cur - idx) mod window)`` with ``cur = length - 1``; the
    ``p >= 0`` predicate *is* the sliding-window mask, so out-of-window
    cells (whose pages may have rotated to trash) never score.
  * ``paged_mla_kernel`` — latent (ckv/krope) pages for absorbed MLA
    decode: scores are ``q_lat . ckv + q_rope . krope`` and the output
    stays in the latent space (the caller up-projects through W_uv), so
    the kernel's HBM traffic is the *compressed* cache — the reason MLA
    pages at the latent rank instead of materialized heads.

Chunked-prefill kernels (one bucketed chunk of a single request; the
engine's ``paged_prefill_apply`` / ``lm_paged_verify`` path):

  * ``paged_prefill_kernel`` — contiguous pages already hold the chunk's
    freshly written K/V (positions ``start..start+n_valid-1``), so the
    chunk's causal queries attend pages only: key validity is the
    written-so-far bound ``idx < start + n_valid`` AND the causal horizon
    ``idx <= start + i``.
  * ``paged_ring_prefill_kernel`` — snapshot-before-write semantics: the
    chunk's writes wrap onto ring cells its own early queries still need,
    so the kernel streams the *pre-write* ring snapshot (ring-arithmetic
    key positions, same ``p >= 0`` liveness mask as decode) plus the
    chunk's own K/V as a separate blocked operand, matching the jnp
    path's gather-before-write contract.
  * ``paged_mla_prefill_kernel`` — absorbed MLA: latent-space queries
    against ckv/krope pages, output stays latent (the caller up-projects
    through W_uv) — per-head K/V are never materialized.

Grid: ``(slots | KV[, n_q_blocks], n_table)`` with the page dimension
sequential ("arbitrary"); the online-softmax state (m, l, acc) lives in
VMEM scratch and carries across a slot's pages, exactly like the kv-block
dimension of ``flash_attention``.  Pages past a slot's valid cells are
skipped at grid level (``pl.when``) — their table entries point at the
trash page (page 0) and cost no MXU cycles.  Prefill additionally skips
(a) whole query blocks past the chunk's ``n_valid`` tail (a mostly-empty
bucket no longer pays full attention tiles for its padding rows) and
(b) pages past each query block's causal horizon.

Layouts (see ref.py): q [slots, KV, G, hd] (prefill: [S, KV, G, hd]);
k/v pages [P, ps, KV, hd]; q_lat [slots, H, R]; ckv pages [P, ps, R];
page_table [slots, n_table] int32 (prefill: one row [n_table]); lengths
[slots] int32 (prefill: meta [2] int32 = start, n_valid).

Quantized (int8) pools add ``k_scale``/``v_scale`` operands [P, ps, KV]
fp32 — one symmetric scale per (page, offset, kv-head) row — streamed
through the same page-table index maps as their int8 data pages.  Dequant
fuses into the online softmax: raw int8 scores are multiplied by the key's
scale per column, probabilities by the value's scale per row before the PV
product — fp pages are never materialized, so HBM reads stay ~1/4 of the
fp pool's.  The jnp oracles in ref.py apply the identical fused math
(same multiply placement), which is what keeps quantized kernel-on vs
kernel-off token-identical.  MLA latent kernels take no scales (the
layout seam rejects quantized latents — rank is a contracted dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import NEG_INF, CompilerParams as _CompilerParams


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                  scale: float, page_size: int, n_table: int, window: int,
                  quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    s = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[s]
    base = p * page_size
    # grid-level skip: cells entirely past the slot's valid tokens (for the
    # ring the valid cell count saturates at the window — beyond that every
    # cell holds a live in-window position)
    limit = length if window == 0 else jnp.minimum(length, window)

    @pl.when(base < limit)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [ps, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)            # [ps, hd]
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G, ps]
        if quantized:
            # fused dequant: raw int8 scores scaled per key column
            sc = sc * ks_ref[0, :, 0].astype(jnp.float32)[None, :]
        idx = base + jax.lax.broadcasted_iota(
            jnp.int32, sc.shape, 1)                       # cell indices
        if window:
            # ring arithmetic: the cell's absolute position; p >= 0 is the
            # window mask (and masks never-written cells of short slots)
            cur = length - 1
            tok = cur - jnp.mod(cur - idx, window)
            sc = jnp.where(tok >= 0, sc, NEG_INF)
        else:
            sc = jnp.where(idx < length, sc, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        pr = jnp.exp(sc - m_new)                          # [G, ps]
        l_scr[...] = l_prev * corr + jnp.sum(pr, axis=1, keepdims=True)
        # fused dequant: probabilities scaled per value row (the softmax
        # denominator stays unscaled — it normalizes probabilities, not
        # values)
        pv = pr * vs_ref[0, :, 0].astype(jnp.float32)[None, :] \
            if quantized else pr
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            pv, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(p == n_table - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pages, v_pages, page_table, lengths, *,
                           window: int = 0, k_scale=None, v_scale=None,
                           interpret: bool = False):
    """q: [slots, KV, G, hd]; k/v_pages: [P, ps, KV, hd];
    page_table: [slots, n_table] int32; lengths: [slots] int32.
    ``window > 0`` selects the ring-cell position mapping.  ``k_scale``/
    ``v_scale`` [P, ps, KV] bf16 mark int8 pages — their blocks stream
    through the same page-table index map and dequant fuses into the
    softmax accumulation.

    Returns [slots, KV, G, hd] in q.dtype.
    """
    slots, KV, G, hd = q.shape
    _, ps, _, _ = k_pages.shape
    n_table = page_table.shape[1]
    scale = hd ** -0.5
    quantized = k_scale is not None

    kernel = functools.partial(_paged_kernel, scale=scale, page_size=ps,
                               n_table=n_table, window=window,
                               quantized=quantized)

    page_spec = pl.BlockSpec((1, ps, 1, hd),
                             lambda s, h, p, pt, ln: (pt[s, p], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda s, h, p, pt, ln: (s, h, 0, 0)),
        # physical page chosen by the prefetched table — the paged gather
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec((1, ps, 1),
                                  lambda s, h, p, pt, ln: (pt[s, p], 0, h))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(slots, KV, n_table),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda s, h, p, pt, ln: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m
            pltpu.VMEM((G, 1), jnp.float32),    # l
            pltpu.VMEM((G, hd), jnp.float32),   # acc
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, *operands)


def _paged_mla_kernel(pt_ref, len_ref, ql_ref, qr_ref, ckv_ref, kr_ref,
                      o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                      page_size: int, n_table: int):
    s = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[s]
    base = p * page_size

    @pl.when(base < length)
    def _compute():
        ql = ql_ref[0].astype(jnp.float32)                # [H, R]
        qr = qr_ref[0].astype(jnp.float32)                # [H, rp]
        ckv = ckv_ref[0].astype(jnp.float32)              # [ps, R]
        kr = kr_ref[0].astype(jnp.float32)                # [ps, rp]
        sc = jax.lax.dot_general(
            ql, ckv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sc = sc + jax.lax.dot_general(
            qr, kr, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sc = sc * scale                                   # [H, ps]
        tok = base + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        sc = jnp.where(tok < length, sc, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        pr = jnp.exp(sc - m_new)                          # [H, ps]
        l_scr[...] = l_prev * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            pr, ckv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # [H, R]
        m_scr[...] = m_new

    @pl.when(p == n_table - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_mla_kernel(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                     lengths, *, scale: float, interpret: bool = False):
    """q_lat: [slots, H, R]; q_rope: [slots, H, rp]; ckv_pages: [P, ps, R];
    krope_pages: [P, ps, rp]; page_table: [slots, n_table] int32; lengths:
    [slots] int32.  ``scale`` is the qk-dimension softmax scale.

    Returns the latent-space output [slots, H, R] in q_lat.dtype.
    """
    slots, H, R = q_lat.shape
    rp = q_rope.shape[-1]
    _, ps, _ = ckv_pages.shape
    n_table = page_table.shape[1]

    kernel = functools.partial(_paged_mla_kernel, scale=scale, page_size=ps,
                               n_table=n_table)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(slots, n_table),
        in_specs=[
            pl.BlockSpec((1, H, R), lambda s, p, pt, ln: (s, 0, 0)),
            pl.BlockSpec((1, H, rp), lambda s, p, pt, ln: (s, 0, 0)),
            pl.BlockSpec((1, ps, R), lambda s, p, pt, ln: (pt[s, p], 0, 0)),
            pl.BlockSpec((1, ps, rp), lambda s, p, pt, ln: (pt[s, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, R), lambda s, p, pt, ln: (s, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),    # m
            pltpu.VMEM((H, 1), jnp.float32),    # l
            pltpu.VMEM((H, R), jnp.float32),    # acc (latent space)
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, H, R), q_lat.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, q_lat, q_rope, ckv_pages, krope_pages)


# ---------------------------------------------------------------------------
# Chunked prefill: one bucketed chunk of a single request vs its pages
# ---------------------------------------------------------------------------

def _prefill_q_block(S: int) -> int:
    """Query-block height: the whole bucket up to 128 rows, 128-row tiles
    beyond (buckets are powers of two, so 128 divides any larger S)."""
    return S if S % 128 else 128


def _online_update(m_scr, l_scr, acc_scr, sc, v, v_scale=None):
    """One masked score block folded into the (m, l, acc) scratch state.
    ``v_scale`` [ps] marks an int8 value block: probabilities are scaled
    per value row before the PV product (fused dequant); the softmax
    denominator stays unscaled — it normalizes probabilities, not
    values."""
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    pr = jnp.exp(sc - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(pr, axis=1, keepdims=True)
    pv = pr if v_scale is None else pr * v_scale[None, :]
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        pv, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _paged_prefill_kernel(pt_ref, meta_ref, q_ref, k_ref, v_ref, *rest,
                          scale: float, page_size: int, n_table: int,
                          q_block: int, groups: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    qi = pl.program_id(1)
    p = pl.program_id(2)
    start = meta_ref[0]
    n_valid = meta_ref[1]
    q0 = qi * q_block

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    limit = start + n_valid                    # keys written so far
    base = p * page_size
    # grid-level skips: a bucket-tail query block (all padding rows) costs
    # no MXU cycles, and a page only scores when it holds a key some query
    # of this block can see (written bound AND the block's causal horizon)
    horizon = jnp.minimum(limit, start + q0 + q_block)

    @pl.when((q0 < n_valid) & (base < horizon))
    def _compute():
        hd = q_ref.shape[-1]
        q = q_ref[:, 0].astype(jnp.float32).reshape(-1, hd)  # [qb*G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)               # [ps, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [qb*G, ps]
        if quantized:
            # fused dequant: raw int8 scores scaled per key column
            sc = sc * ks_ref[0, :, 0].astype(jnp.float32)[None, :]
        r = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0)
        c = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        qpos = start + q0 + r // groups        # row r = query (r // G)
        kidx = base + c
        sc = jnp.where((kidx < limit) & (kidx <= qpos), sc, NEG_INF)
        _online_update(m_scr, l_scr, acc_scr, sc, v,
                       vs_ref[0, :, 0].astype(jnp.float32) if quantized else None)

    @pl.when(p == n_table - 1)
    def _finish():
        qb, _, G, hd = o_ref.shape
        o_ref[:, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)) \
            .reshape(qb, G, hd).astype(o_ref.dtype)


def paged_prefill_kernel(q, k_pages, v_pages, page_table, meta, *,
                         k_scale=None, v_scale=None,
                         interpret: bool = False):
    """Contiguous-layout chunked prefill.  q: [S, KV, G, hd] — one
    request's bucketed chunk (post-rope); k/v_pages: [P, ps, KV, hd] —
    the pool AFTER the chunk's K/V were scattered in; page_table: [n]
    int32 — this request's row (0-padded tail = trash); meta: [2] int32 =
    (start, n_valid).  Query i holds absolute position ``start + i``;
    padding rows (i >= n_valid) are skipped at grid level and come back 0.
    ``k_scale``/``v_scale`` [P, ps, KV] bf16 mark int8 pages (fused
    dequant, same page-table streaming).

    Returns [S, KV, G, hd] in q.dtype.
    """
    S, KV, G, hd = q.shape
    ps = k_pages.shape[1]
    n_table = page_table.shape[0]
    qb = _prefill_q_block(S)
    scale = hd ** -0.5
    quantized = k_scale is not None

    kernel = functools.partial(_paged_prefill_kernel, scale=scale,
                               page_size=ps, n_table=n_table, q_block=qb,
                               groups=G, quantized=quantized)

    page_spec = pl.BlockSpec((1, ps, 1, hd),
                             lambda h, qi, p, pt, mt: (pt[p], 0, h, 0))
    in_specs = [
        pl.BlockSpec((qb, 1, G, hd),
                     lambda h, qi, p, pt, mt: (qi, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        scale_spec = pl.BlockSpec((1, ps, 1),
                                  lambda h, qi, p, pt, mt: (pt[p], 0, h))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(KV, S // qb, n_table),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((qb, 1, G, hd),
                               lambda h, qi, p, pt, mt: (qi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qb * G, 1), jnp.float32),    # m
            pltpu.VMEM((qb * G, 1), jnp.float32),    # l
            pltpu.VMEM((qb * G, hd), jnp.float32),   # acc
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, meta, *operands)



def _paged_ring_prefill_kernel(pt_ref, meta_ref, q_ref, k_ref, v_ref,
                               ck_ref, cv_ref, *rest,
                               scale: float, page_size: int, n_table: int,
                               n_chunk: int, q_block: int, groups: int,
                               window: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    qi = pl.program_id(1)
    p = pl.program_id(2)
    start = meta_ref[0]
    n_valid = meta_ref[1]
    q0 = qi * q_block

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _scores(k):
        hd = q_ref.shape[-1]
        q = q_ref[:, 0].astype(jnp.float32).reshape(-1, hd)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [qb*G, ·]
        r = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0)
        c = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        return sc, start + q0 + r // groups, c

    # --- pre-write ring snapshot: positions < start, ring arithmetic ---
    # live cells hold positions [max(0, start - window), start) — exactly
    # min(start, window) of them, from cell 0 up
    base = p * page_size

    @pl.when((p < n_table) & (q0 < n_valid)
             & (base < jnp.minimum(start, window)))
    def _ring():
        k = k_ref[0, :, 0].astype(jnp.float32)
        v = v_ref[0, :, 0].astype(jnp.float32)
        sc, qpos, c = _scores(k)
        if quantized:
            # fused dequant of the int8 snapshot (the chunk operands below
            # are freshly projected fp — never quantized)
            sc = sc * ks_ref[0, :, 0].astype(jnp.float32)[None, :]
        idx = base + c
        cur = start - 1
        kpos = cur - jnp.mod(cur - idx, window)  # < 0 = never written
        # snapshot keys all precede the chunk, so causality is implied;
        # the window mask drops wrapped-over and out-of-window cells
        sc = jnp.where((kpos >= 0) & (kpos > qpos - window), sc, NEG_INF)
        _online_update(m_scr, l_scr, acc_scr, sc, v,
                       vs_ref[0, :, 0].astype(jnp.float32) if quantized else None)

    # --- the chunk's own K/V (freshly projected, NOT read from pages) ---
    j0 = (p - n_table) * page_size

    @pl.when((p >= n_table) & (q0 < n_valid)
             & (j0 < jnp.minimum(n_valid, q0 + q_block)))
    def _chunk():
        k = ck_ref[:, 0].astype(jnp.float32)                 # [ps, hd]
        v = cv_ref[:, 0].astype(jnp.float32)
        sc, qpos, c = _scores(k)
        j = j0 + c
        kpos = start + j
        sc = jnp.where((j < n_valid) & (kpos <= qpos)
                       & (kpos > qpos - window), sc, NEG_INF)
        _online_update(m_scr, l_scr, acc_scr, sc, v)

    @pl.when(p == n_table + n_chunk - 1)
    def _finish():
        qb, _, G, hd = o_ref.shape
        o_ref[:, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)) \
            .reshape(qb, G, hd).astype(o_ref.dtype)


def paged_ring_prefill_kernel(q, k_pages, v_pages, chunk_k, chunk_v,
                              page_table, meta, *, window: int,
                              k_scale=None, v_scale=None,
                              interpret: bool = False):
    """Ring-layout (sliding-window/local) chunked prefill,
    snapshot-before-write semantics.  q: [S, KV, G, hd]; k/v_pages:
    [P, ps, KV, hd] — the pool BEFORE the chunk's writes (the chunk wraps
    onto cells its own early queries still need); chunk_k/chunk_v:
    [S, KV, hd] — the chunk's own post-rope keys/values; page_table: [n]
    int32 — the request's ring of ``window // ps`` cells; meta: [2] int32
    = (start, n_valid).  The grid walks ring cells then chunk blocks; the
    sliding-window mask keeps every wrapped-over snapshot cell out of the
    scores.  ``k_scale``/``v_scale`` [P, ps, KV] bf16 mark int8 snapshot
    pages (fused dequant; the chunk operands stay fp).
    Returns [S, KV, G, hd] in q.dtype.
    """
    S, KV, G, hd = q.shape
    ps = k_pages.shape[1]
    n_table = page_table.shape[0]
    qb = _prefill_q_block(S)
    scale = hd ** -0.5
    quantized = k_scale is not None
    pad = (-S) % ps                            # block chunk keys at ps
    if pad:
        chunk_k = jnp.pad(chunk_k, ((0, pad), (0, 0), (0, 0)))
        chunk_v = jnp.pad(chunk_v, ((0, pad), (0, 0), (0, 0)))
    n_chunk = chunk_k.shape[0] // ps

    kernel = functools.partial(_paged_ring_prefill_kernel, scale=scale,
                               page_size=ps, n_table=n_table,
                               n_chunk=n_chunk, q_block=qb, groups=G,
                               window=window, quantized=quantized)

    # chunk-phase steps clamp the page index to the trash page and ring-
    # phase steps clamp the chunk block to 0: the inactive operand's DMA
    # repeats one index, which the pipeline dedupes — no extra HBM traffic
    def _page_index(h, qi, p, pt, mt):
        return (jnp.where(p < n_table, pt[jnp.minimum(p, n_table - 1)], 0),
                0, h, 0)

    def _chunk_index(h, qi, p, pt, mt):
        return (jnp.where(p >= n_table, p - n_table, 0), h, 0)

    in_specs = [
        pl.BlockSpec((qb, 1, G, hd),
                     lambda h, qi, p, pt, mt: (qi, h, 0, 0)),
        pl.BlockSpec((1, ps, 1, hd), _page_index),
        pl.BlockSpec((1, ps, 1, hd), _page_index),
        pl.BlockSpec((ps, 1, hd), _chunk_index),
        pl.BlockSpec((ps, 1, hd), _chunk_index),
    ]
    operands = [q, k_pages, v_pages, chunk_k, chunk_v]
    if quantized:
        def _scale_index(h, qi, p, pt, mt):
            return (jnp.where(p < n_table,
                              pt[jnp.minimum(p, n_table - 1)], 0), 0, h)
        in_specs += [pl.BlockSpec((1, ps, 1), _scale_index),
                     pl.BlockSpec((1, ps, 1), _scale_index)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(KV, S // qb, n_table + n_chunk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((qb, 1, G, hd),
                               lambda h, qi, p, pt, mt: (qi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qb * G, 1), jnp.float32),    # m
            pltpu.VMEM((qb * G, 1), jnp.float32),    # l
            pltpu.VMEM((qb * G, hd), jnp.float32),   # acc
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, meta, *operands)


def _paged_mla_prefill_kernel(pt_ref, meta_ref, ql_ref, qr_ref, ckv_ref,
                              kr_ref, o_ref, m_scr, l_scr, acc_scr, *,
                              scale: float, page_size: int, n_table: int,
                              q_block: int, heads: int):
    qi = pl.program_id(0)
    p = pl.program_id(1)
    start = meta_ref[0]
    n_valid = meta_ref[1]
    q0 = qi * q_block

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    limit = start + n_valid
    base = p * page_size
    horizon = jnp.minimum(limit, start + q0 + q_block)

    @pl.when((q0 < n_valid) & (base < horizon))
    def _compute():
        R = ql_ref.shape[-1]
        rp = qr_ref.shape[-1]
        ql = ql_ref[...].astype(jnp.float32).reshape(-1, R)  # [qb*H, R]
        qr = qr_ref[...].astype(jnp.float32).reshape(-1, rp)
        ckv = ckv_ref[0].astype(jnp.float32)                 # [ps, R]
        kr = kr_ref[0].astype(jnp.float32)                   # [ps, rp]
        sc = jax.lax.dot_general(
            ql, ckv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sc = sc + jax.lax.dot_general(
            qr, kr, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sc = sc * scale                                      # [qb*H, ps]
        r = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0)
        c = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        qpos = start + q0 + r // heads
        kidx = base + c
        sc = jnp.where((kidx < limit) & (kidx <= qpos), sc, NEG_INF)
        _online_update(m_scr, l_scr, acc_scr, sc, ckv)       # acc latent

    @pl.when(p == n_table - 1)
    def _finish():
        qb, H, R = o_ref.shape
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)) \
            .reshape(qb, H, R).astype(o_ref.dtype)


def paged_mla_prefill_kernel(q_lat, q_rope, ckv_pages, krope_pages,
                             page_table, meta, *, scale: float,
                             interpret: bool = False):
    """Absorbed-MLA chunked prefill against latent pages (contiguous).
    q_lat: [S, H, R] — the chunk's queries absorbed through W_uk; q_rope:
    [S, H, rp]; ckv/krope_pages hold the chunk's freshly written latents;
    page_table: [n] int32; meta: [2] int32 = (start, n_valid); ``scale``
    the qk-dimension softmax scale.  Pages stream as compressed latents —
    per-head K/V are never materialized — and the output stays in the
    latent space [S, H, R] (the caller up-projects through W_uv).
    """
    S, H, R = q_lat.shape
    rp = q_rope.shape[-1]
    ps = ckv_pages.shape[1]
    n_table = page_table.shape[0]
    qb = _prefill_q_block(S)

    kernel = functools.partial(_paged_mla_prefill_kernel, scale=scale,
                               page_size=ps, n_table=n_table, q_block=qb,
                               heads=H)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S // qb, n_table),
        in_specs=[
            pl.BlockSpec((qb, H, R), lambda qi, p, pt, mt: (qi, 0, 0)),
            pl.BlockSpec((qb, H, rp), lambda qi, p, pt, mt: (qi, 0, 0)),
            pl.BlockSpec((1, ps, R), lambda qi, p, pt, mt: (pt[p], 0, 0)),
            pl.BlockSpec((1, ps, rp), lambda qi, p, pt, mt: (pt[p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((qb, H, R),
                               lambda qi, p, pt, mt: (qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qb * H, 1), jnp.float32),    # m
            pltpu.VMEM((qb * H, 1), jnp.float32),    # l
            pltpu.VMEM((qb * H, R), jnp.float32),    # acc (latent space)
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, H, R), q_lat.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, meta, q_lat, q_rope, ckv_pages, krope_pages)
