"""Paged-attention decode TPU kernel (vLLM-style, scalar-prefetched pages).

One decode step attends each slot's single query against K/V scattered
across a global page pool.  The page table is a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``): BlockSpec index maps read it to decide
which physical page to DMA into VMEM for each grid step, so HBM traffic is
``pages_held``, not ``slots x max_pages`` — the whole point of paging.

Grid: ``(slots, KV, n_table)`` with the page dimension sequential
("arbitrary"); the online-softmax state (m, l, acc) lives in VMEM scratch
and carries across a slot's pages, exactly like the kv-block dimension of
``flash_attention``.  Pages past a slot's length are skipped at grid level
(``pl.when``) — their table entries point at the trash page (page 0) and
cost no MXU cycles.

Layouts (see ref.py): q [slots, KV, G, hd]; k/v pages [P, ps, KV, hd];
page_table [slots, n_table] int32; lengths [slots] int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import NEG_INF, CompilerParams as _CompilerParams


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                  n_table: int):
    s = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[s]
    base = p * page_size

    # grid-level skip: page entirely past the slot's valid tokens
    @pl.when(base < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [ps, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)            # [ps, hd]
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G, ps]
        tok = base + jax.lax.broadcasted_iota(
            jnp.int32, sc.shape, 1)                       # in-page positions
        sc = jnp.where(tok < length, sc, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        pr = jnp.exp(sc - m_new)                          # [G, ps]
        l_scr[...] = l_prev * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(p == n_table - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pages, v_pages, page_table, lengths, *,
                           interpret: bool = False):
    """q: [slots, KV, G, hd]; k/v_pages: [P, ps, KV, hd];
    page_table: [slots, n_table] int32; lengths: [slots] int32.

    Returns [slots, KV, G, hd] in q.dtype.
    """
    slots, KV, G, hd = q.shape
    _, ps, _, _ = k_pages.shape
    n_table = page_table.shape[1]
    scale = hd ** -0.5

    kernel = functools.partial(_paged_kernel, scale=scale, page_size=ps,
                               n_table=n_table)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(slots, KV, n_table),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda s, h, p, pt, ln: (s, h, 0, 0)),
            # physical page chosen by the prefetched table — the paged gather
            pl.BlockSpec((1, ps, 1, hd),
                         lambda s, h, p, pt, ln: (pt[s, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda s, h, p, pt, ln: (pt[s, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda s, h, p, pt, ln: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m
            pltpu.VMEM((G, 1), jnp.float32),    # l
            pltpu.VMEM((G, hd), jnp.float32),   # acc
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)
