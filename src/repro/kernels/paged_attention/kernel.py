"""Paged-attention decode TPU kernels (vLLM-style, scalar-prefetched pages).

One decode step attends each slot's single query against a cache scattered
across a global page pool.  The page table is a *scalar-prefetch* operand
(``pltpu.PrefetchScalarGridSpec``): BlockSpec index maps read it to decide
which physical page to DMA into VMEM for each grid step, so HBM traffic is
``pages_held``, not ``slots x max_pages`` — the whole point of paging.

Two kernels, one per page geometry (see ``repro.serving.layouts``):

  * ``paged_attention_kernel`` — per-head k/v pages for GQA, covering both
    the contiguous ("kv") and ring-wrapped ("window") layouts.  For the
    ring, a cell's absolute position is arithmetic, not storage:
    ``p = cur - ((cur - idx) mod window)`` with ``cur = length - 1``; the
    ``p >= 0`` predicate *is* the sliding-window mask, so out-of-window
    cells (whose pages may have rotated to trash) never score.
  * ``paged_mla_kernel`` — latent (ckv/krope) pages for absorbed MLA
    decode: scores are ``q_lat . ckv + q_rope . krope`` and the output
    stays in the latent space (the caller up-projects through W_uv), so
    the kernel's HBM traffic is the *compressed* cache — the reason MLA
    pages at the latent rank instead of materialized heads.

Grid: ``(slots[, KV], n_table)`` with the page dimension sequential
("arbitrary"); the online-softmax state (m, l, acc) lives in VMEM scratch
and carries across a slot's pages, exactly like the kv-block dimension of
``flash_attention``.  Pages past a slot's valid cells are skipped at grid
level (``pl.when``) — their table entries point at the trash page (page 0)
and cost no MXU cycles.

Layouts (see ref.py): q [slots, KV, G, hd]; k/v pages [P, ps, KV, hd];
q_lat [slots, H, R]; ckv pages [P, ps, R]; page_table [slots, n_table]
int32; lengths [slots] int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import NEG_INF, CompilerParams as _CompilerParams


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                  n_table: int, window: int):
    s = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[s]
    base = p * page_size
    # grid-level skip: cells entirely past the slot's valid tokens (for the
    # ring the valid cell count saturates at the window — beyond that every
    # cell holds a live in-window position)
    limit = length if window == 0 else jnp.minimum(length, window)

    @pl.when(base < limit)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [ps, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)            # [ps, hd]
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G, ps]
        idx = base + jax.lax.broadcasted_iota(
            jnp.int32, sc.shape, 1)                       # cell indices
        if window:
            # ring arithmetic: the cell's absolute position; p >= 0 is the
            # window mask (and masks never-written cells of short slots)
            cur = length - 1
            tok = cur - jnp.mod(cur - idx, window)
            sc = jnp.where(tok >= 0, sc, NEG_INF)
        else:
            sc = jnp.where(idx < length, sc, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        pr = jnp.exp(sc - m_new)                          # [G, ps]
        l_scr[...] = l_prev * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(p == n_table - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pages, v_pages, page_table, lengths, *,
                           window: int = 0, interpret: bool = False):
    """q: [slots, KV, G, hd]; k/v_pages: [P, ps, KV, hd];
    page_table: [slots, n_table] int32; lengths: [slots] int32.
    ``window > 0`` selects the ring-cell position mapping.

    Returns [slots, KV, G, hd] in q.dtype.
    """
    slots, KV, G, hd = q.shape
    _, ps, _, _ = k_pages.shape
    n_table = page_table.shape[1]
    scale = hd ** -0.5

    kernel = functools.partial(_paged_kernel, scale=scale, page_size=ps,
                               n_table=n_table, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(slots, KV, n_table),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda s, h, p, pt, ln: (s, h, 0, 0)),
            # physical page chosen by the prefetched table — the paged gather
            pl.BlockSpec((1, ps, 1, hd),
                         lambda s, h, p, pt, ln: (pt[s, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda s, h, p, pt, ln: (pt[s, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda s, h, p, pt, ln: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m
            pltpu.VMEM((G, 1), jnp.float32),    # l
            pltpu.VMEM((G, hd), jnp.float32),   # acc
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)


def _paged_mla_kernel(pt_ref, len_ref, ql_ref, qr_ref, ckv_ref, kr_ref,
                      o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                      page_size: int, n_table: int):
    s = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[s]
    base = p * page_size

    @pl.when(base < length)
    def _compute():
        ql = ql_ref[0].astype(jnp.float32)                # [H, R]
        qr = qr_ref[0].astype(jnp.float32)                # [H, rp]
        ckv = ckv_ref[0].astype(jnp.float32)              # [ps, R]
        kr = kr_ref[0].astype(jnp.float32)                # [ps, rp]
        sc = jax.lax.dot_general(
            ql, ckv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sc = sc + jax.lax.dot_general(
            qr, kr, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sc = sc * scale                                   # [H, ps]
        tok = base + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        sc = jnp.where(tok < length, sc, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        pr = jnp.exp(sc - m_new)                          # [H, ps]
        l_scr[...] = l_prev * corr + jnp.sum(pr, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            pr, ckv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # [H, R]
        m_scr[...] = m_new

    @pl.when(p == n_table - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_mla_kernel(q_lat, q_rope, ckv_pages, krope_pages, page_table,
                     lengths, *, scale: float, interpret: bool = False):
    """q_lat: [slots, H, R]; q_rope: [slots, H, rp]; ckv_pages: [P, ps, R];
    krope_pages: [P, ps, rp]; page_table: [slots, n_table] int32; lengths:
    [slots] int32.  ``scale`` is the qk-dimension softmax scale.

    Returns the latent-space output [slots, H, R] in q_lat.dtype.
    """
    slots, H, R = q_lat.shape
    rp = q_rope.shape[-1]
    _, ps, _ = ckv_pages.shape
    n_table = page_table.shape[1]

    kernel = functools.partial(_paged_mla_kernel, scale=scale, page_size=ps,
                               n_table=n_table)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(slots, n_table),
        in_specs=[
            pl.BlockSpec((1, H, R), lambda s, p, pt, ln: (s, 0, 0)),
            pl.BlockSpec((1, H, rp), lambda s, p, pt, ln: (s, 0, 0)),
            pl.BlockSpec((1, ps, R), lambda s, p, pt, ln: (pt[s, p], 0, 0)),
            pl.BlockSpec((1, ps, rp), lambda s, p, pt, ln: (pt[s, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, R), lambda s, p, pt, ln: (s, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),    # m
            pltpu.VMEM((H, 1), jnp.float32),    # l
            pltpu.VMEM((H, R), jnp.float32),    # acc (latent space)
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, H, R), q_lat.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, q_lat, q_rope, ckv_pages, krope_pages)
