"""jit'd public wrapper for the RG-LRU scan kernel (padding + dtype mgmt)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import lru_scan_padded


@functools.partial(jax.jit, static_argnames=("interpret", "block_t"))
def lru_scan(log_a, gated, *, interpret: bool = False, block_t: int = 128):
    """Drop-in for the associative-scan path in models/rglru.py.

    log_a, gated: [B, S, W] fp32 -> h [B, S, W] fp32."""
    B, S, W = gated.shape
    bt = min(block_t, max(S, 8))
    S_p = -(-S // bt) * bt
    W_p = -(-W // 128) * 128 if W > 128 else W
    la = jnp.pad(log_a.astype(jnp.float32),
                 ((0, 0), (0, S_p - S), (0, W_p - W)))
    x = jnp.pad(gated.astype(jnp.float32),
                ((0, 0), (0, S_p - S), (0, W_p - W)))
    h = lru_scan_padded(la, x, block_t=bt, interpret=interpret)
    return h[:, :S, :W]
