"""RG-LRU linear-recurrence TPU kernel (pl.pallas_call + BlockSpec).

Evaluates  h_t = a_t * h_{t-1} + x_t  (elementwise, a_t = exp(log_a_t))
over the sequence with the state carried in VMEM scratch across a
*sequential* time-block grid dimension.

TPU adaptation: the GPU formulation of linear-scan layers leans on warp
shuffles / Blelloch trees; on TPU the VPU prefers a short unrolled serial
loop over a lane-parallel [block_b, width] tile — the recurrence is serial
in t but fully vector-parallel in (batch, width), which matches VREG lanes.
Width is tiled over the grid's parallel dimensions so the working set
(3 tiles + state) stays in VMEM.

Grid: (nb, nw, nt) with nt sequential; state scratch [block_b, block_w].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _lru_kernel(log_a_ref, x_ref, o_ref, h_scr, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def body(i, h):
        a = jnp.exp(log_a_ref[:, i, :])
        h = a * h + x_ref[:, i, :]
        o_ref[:, i, :] = h
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_t, body, h_scr[...])


def lru_scan_padded(log_a, x, *, block_b: int = 8, block_t: int = 128,
                    block_w: int = 128, interpret: bool = False):
    """log_a, x: [B, S, W] fp32 -> h: [B, S, W] fp32 (prefix recurrence).

    B, S, W are padded to block multiples by the caller (ops.py).
    """
    B, S, W = x.shape
    block_b = min(block_b, B)
    block_t = min(block_t, S)
    block_w = min(block_w, W)
    nb = pl.cdiv(B, block_b)
    nt = pl.cdiv(S, block_t)
    nw = pl.cdiv(W, block_w)

    kernel = functools.partial(_lru_kernel, block_t=block_t)
    spec = pl.BlockSpec((block_b, block_t, block_w),
                        lambda ib, iw, it: (ib, it, iw))
    return pl.pallas_call(
        kernel,
        grid=(nb, nw, nt),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_w), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, x)
