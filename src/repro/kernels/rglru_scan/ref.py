"""Pure-jnp oracle for the RG-LRU scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_scan_ref(log_a, x):
    """h_t = exp(log_a_t) * h_{t-1} + x_t, h_0 = x_0-style prefix scan.

    log_a, x: [B, S, W] -> [B, S, W] fp32."""
    def step(h, inp):
        la, xt = inp
        h = jnp.exp(la) * h + xt
        return h, h

    la = log_a.astype(jnp.float32).transpose(1, 0, 2)
    xt = x.astype(jnp.float32).transpose(1, 0, 2)
    h0 = jnp.zeros_like(xt[0])
    _, hs = jax.lax.scan(step, h0, (la, xt))
    return hs.transpose(1, 0, 2)
