"""Pure-jnp oracle for the WKV6 kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0):
    """r,k,v,w: [BH, S, hd] fp32; u: [BH, hd]; s0: [BH, hd, hd].

    Returns (out [BH, S, hd], s_last [BH, hd, hd])."""
    rf, kf, vf, wf = (t.astype(jnp.float32).transpose(1, 0, 2)
                      for t in (r, k, v, w))

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]          # [BH, hd, hd]
        out = jnp.einsum("bi,bij->bj", r_t, s + u[..., :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, out

    s_last, out = jax.lax.scan(step, s0.astype(jnp.float32), (rf, kf, vf, wf))
    return out.transpose(1, 0, 2), s_last
