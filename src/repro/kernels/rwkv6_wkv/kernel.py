"""RWKV-6 WKV recurrence TPU kernel (pl.pallas_call + BlockSpec).

Per head, with outer-product state S in R^{hd x hd}:
    out_t = r_t^T (S_{t-1} + (u * k_t) v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

TPU adaptation: CUDA RWKV kernels assign one thread per channel and rely on
shared-memory broadcasts; here the state tile [hd, hd] (64x64 = one MXU tile)
lives in VMEM scratch and the serial time loop runs rank-1 updates as VPU
outer products — (batch*heads) fills the parallel grid dimension, time blocks
are the sequential dimension carrying the state.

Grid: (BH, nt) with nt sequential; layouts r/k/v/w: [BH, S, hd].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                s_scr, *, block_t: int, nt: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    u = u_ref[0][:, None]                           # [hd, 1]: u_i broadcast

    def body(i, s):
        r = r_ref[0, i, :][None, :]                 # [1, hd]
        k = k_ref[0, i, :][None, :]
        v = v_ref[0, i, :][None, :]
        w = w_ref[0, i, :][None, :]
        kv = k.T @ v                                # [hd, hd] rank-1
        out = r @ (s + u * kv)                      # [1, hd]
        o_ref[0, i, :] = out[0]
        return w.T * s + kv

    s_scr[...] = jax.lax.fori_loop(0, block_t, body, s_scr[...])

    @pl.when(it == nt - 1)
    def _finish():
        sT_ref[0] = s_scr[...]


def wkv6_padded(r, k, v, w, u, s0, *, block_t: int = 64,
                interpret: bool = False):
    """r,k,v,w: [BH, S, hd] fp32; u: [BH_heads? no — [BH, hd]]; s0: [BH, hd, hd].

    Returns (out [BH, S, hd], s_last [BH, hd, hd]) fp32.  S must be a
    multiple of block_t (ops.py pads; padded steps use w=1, k=0 so the state
    is unchanged).
    """
    BH, S, hd = r.shape
    block_t = min(block_t, S)
    nt = pl.cdiv(S, block_t)

    kernel = functools.partial(_wkv_kernel, block_t=block_t, nt=nt)
    seq_spec = pl.BlockSpec((1, block_t, hd), lambda bh, it: (bh, it, 0))
    return pl.pallas_call(
        kernel,
        grid=(BH, nt),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hd), lambda bh, it: (bh, 0)),          # u
            pl.BlockSpec((1, hd, hd), lambda bh, it: (bh, 0, 0)),   # s0
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, hd, hd), lambda bh, it: (bh, 0, 0)),   # s_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
