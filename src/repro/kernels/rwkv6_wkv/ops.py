"""jit'd public wrapper for the WKV6 kernel: model layout + padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv6_padded


@functools.partial(jax.jit, static_argnames=("interpret", "block_t"))
def wkv6(r, k, v, w, u, s0, *, interpret: bool = False, block_t: int = 64):
    """Model layout: r,k,v,w [B,S,H,hd]; u [H,hd]; s0 [B,H,hd,hd].

    Returns (out [B,S,H,hd] fp32, s_last [B,H,hd,hd] fp32) — drop-in for
    models.rwkv6.wkv_scan."""
    B, S, H, hd = r.shape
    bt = min(block_t, max(S, 8))
    S_p = -(-S // bt) * bt

    def flat(t, pad_value=0.0):
        t = t.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        return jnp.pad(t, ((0, 0), (0, S_p - S), (0, 0)),
                       constant_values=pad_value)

    rf, kf, vf = flat(r), flat(k), flat(v)
    wf = flat(w, pad_value=1.0)          # padded steps: w=1, k=0 -> state fixed
    uf = jnp.broadcast_to(u.astype(jnp.float32)[None], (B, H, hd)
                          ).reshape(B * H, hd)
    s0f = s0.astype(jnp.float32).reshape(B * H, hd, hd)
    out, s_last = wkv6_padded(rf, kf, vf, wf, uf, s0f, block_t=bt,
                              interpret=interpret)
    out = out[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return out, s_last.reshape(B, H, hd, hd)
