"""TPU v5e hardware constants (the TARGET; this container runs on CPU)."""

PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (approx, v5e 2D torus)
HBM_BYTES = 16 << 30            # 16 GiB per chip

# cross-pod (data-center network / optical) — used for the "pod" axis
DCN_BW = 25e9                   # bytes/s per host pair, conservative
