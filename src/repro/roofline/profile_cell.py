import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler: lower one cell and print the top FLOP / HBM-byte /
collective contributors with op metadata — the 'profile' used by the §Perf
hypothesis->change->measure loop (no real hardware; the lowered IR is the
profile, per the Pallas dry-run methodology).

  PYTHONPATH=src python -m repro.roofline.profile_cell \
      --arch qwen2.5-14b --shape train_4k --mesh single --mode fsdp
"""
import argparse

from repro.launch.dryrun import lower_cell
from repro.roofline.hlo_parse import analyze_module
from repro.roofline import hw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--mode", default="paper")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--allreduce-override", default=None)
    ap.add_argument("--rules", default=None)
    ap.add_argument("--q-block", type=int, default=None)
    ap.add_argument("--kv-block", type=int, default=None)
    ap.add_argument("--attn-remat", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()

    overrides = {}
    if args.microbatch is not None:
        overrides["microbatch"] = args.microbatch
    if args.remat:
        overrides["remat"] = args.remat
    if args.allreduce_override:
        overrides["allreduce"] = args.allreduce_override
    if args.q_block:
        overrides["q_block"] = args.q_block
    if args.kv_block:
        overrides["kv_block"] = args.kv_block
    if args.attn_remat:
        overrides["attn_remat"] = True
    if args.rules:
        overrides["rules"] = {
            k: (v if v not in ("None", "none", "") else None)
            for k, v in (kv.split("=") for kv in args.rules.split(","))}

    lowered, mesh, cfg = lower_cell(args.arch, args.shape, args.mesh,
                                    args.mode, overrides or None)
    compiled = lowered.compile()
    stats = analyze_module(compiled.as_text())
    ma = compiled.memory_analysis()

    print(f"=== {args.arch} {args.shape} {args.mesh} {args.mode} "
          f"overrides={overrides}")
    print(f"compute {stats.flops/hw.PEAK_FLOPS_BF16:10.3f}s   "
          f"memory {stats.hbm_bytes/hw.HBM_BW:10.3f}s   "
          f"collective {stats.wire_bytes_total/hw.ICI_BW_PER_LINK:10.3f}s   "
          f"peak/dev {(ma.argument_size_in_bytes+ma.output_size_in_bytes+ma.temp_size_in_bytes-ma.alias_size_in_bytes)/2**30:.1f} GiB")

    print(f"\n-- top FLOP contributors (of {stats.flops:.3e} total)")
    for c in stats.top_flops(args.top):
        print(f"  {c.flops:9.3e}  x{c.multiplicity:<6.0f} {c.shape:34s} "
              f"{c.meta[-70:]}")
    print(f"\n-- top HBM-byte contributors (of {stats.hbm_bytes:.3e} total)")
    for c in stats.top_bytes(args.top):
        print(f"  {c.bytes:9.3e}  x{c.multiplicity:<6.0f} {c.opcode:22s} "
              f"{c.shape:30s} {c.meta[-60:]}")
    print(f"\n-- top collectives (wire model, of "
          f"{stats.wire_bytes_total:.3e} total)")
    for c in stats.top_collectives(args.top):
        print(f"  {c.wire_bytes:9.3e}  x{c.multiplicity:<6.0f} "
              f"{c.kind:20s} buf={c.result_bytes:.2e} p={c.group_size}")


if __name__ == "__main__":
    main()
