"""Roofline synthesis: dry-run JSON records -> three-term roofline table.

Terms (per device, per step, seconds; all inputs are per-device quantities
from the post-SPMD HLO):

  compute    = dot_flops / PEAK_FLOPS_BF16
  memory     = hbm_bytes / HBM_BW
  collective = wire_bytes / ICI_BW_PER_LINK

The bottleneck is the max term (perfect-overlap assumption); est. MFU =
compute / max(...); MODEL_FLOPS ratio = 6·N·D-style analytic flops over the
compiled global flops (how much compiled compute is "useful" — catches
remat/redundancy waste).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from repro.roofline import hw


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    mode: str
    devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    est_step_s: float
    est_mfu: float              # compiled-flops MFU upper bound
    model_mfu: float            # useful-flops (6ND) MFU upper bound
    model_to_hlo: float         # MODEL_FLOPS / (global HLO flops)
    peak_bytes_per_dev: float
    fits_hbm: bool
    compile_s: float

    @property
    def cell(self) -> str:
        return f"{self.arch}/{self.shape}/{self.mesh}/{self.mode}"


def row_from_record(rec: dict) -> Optional[RooflineRow]:
    if not rec.get("ok"):
        return None
    st = rec["hlo_stats"]
    n_dev = rec["devices"]
    flops_dev = st["dot_flops"] + st["conv_flops"]
    wire = st["wire_bytes"]
    if rec.get("mode") == "compressed":
        wire *= 0.5     # CPU fallback lowers an fp32 wire; TPU wire is bf16
    compute_s = flops_dev / hw.PEAK_FLOPS_BF16
    memory_s = st["hbm_bytes"] / hw.HBM_BW
    coll_s = wire / hw.ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    est = max(terms.values())
    model_flops_dev = rec["model_flops_global"] / n_dev
    peak = rec["memory"]["peak_estimate_bytes"]
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        mode=rec["mode"], devices=n_dev,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck, est_step_s=est,
        est_mfu=compute_s / est if est else 0.0,
        model_mfu=(model_flops_dev / hw.PEAK_FLOPS_BF16) / est if est else 0.0,
        model_to_hlo=(rec["model_flops_global"] /
                      (flops_dev * n_dev) if flops_dev else 0.0),
        peak_bytes_per_dev=peak,
        fits_hbm=peak <= hw.HBM_BYTES,
        compile_s=rec.get("compile_s", 0.0),
    )


def load_rows(results_dir, include_tags: bool = False) -> List[RooflineRow]:
    rows = []
    for f in sorted(Path(results_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("tag") and not include_tags:
            continue                      # hillclimb variants: §Perf only
        row = row_from_record(rec)
        if row is not None:
            if rec.get("tag"):
                row.mode = f"{row.mode}+{rec['tag']}"
            rows.append(row)
    return rows


def format_markdown(rows: List[RooflineRow]) -> str:
    hdr = ("| cell | compute s | memory s | collective s | bottleneck | "
           "est MFU | model MFU | model/HLO | peak GB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r.cell} | {r.compute_s:.3f} | {r.memory_s:.3f} | "
            f"{r.collective_s:.3f} | {r.bottleneck} | {r.est_mfu:.2%} | "
            f"{r.model_mfu:.2%} | {r.model_to_hlo:.2f} | "
            f"{r.peak_bytes_per_dev/2**30:.1f} | "
            f"{'y' if r.fits_hbm else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def format_csv(rows: List[RooflineRow]) -> str:
    out = ["arch,shape,mesh,mode,devices,compute_s,memory_s,collective_s,"
           "bottleneck,est_mfu,model_mfu,model_to_hlo,peak_gb_dev,fits_hbm,"
           "compile_s"]
    for r in rows:
        out.append(
            f"{r.arch},{r.shape},{r.mesh},{r.mode},{r.devices},"
            f"{r.compute_s:.6f},{r.memory_s:.6f},{r.collective_s:.6f},"
            f"{r.bottleneck},{r.est_mfu:.4f},{r.model_mfu:.4f},"
            f"{r.model_to_hlo:.4f},{r.peak_bytes_per_dev/2**30:.3f},"
            f"{int(r.fits_hbm)},{r.compile_s:.1f}")
    return "\n".join(out) + "\n"
